"""SLO control plane acceptance: multi-window burn-rate math, the full
alert lifecycle under seeded chaos (fire during a crash / partition,
clear after recovery — deterministic ticks, not sleeps), the live HTTP
endpoint diffed byte-for-byte against its in-process sources, and the
trace-tick join between tracer instants and time-series samples.
"""

import json
import os
import sys
import urllib.request

import numpy as np
import pytest

import jax

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))

from repro.chaos import ChaosTransport, FaultInjector  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.obs import (Alert, DecisionLog, MetricRegistry,  # noqa: E402
                       Objective, ObsServer, SLOMonitor, SpanTracer,
                       TimeSeriesStore, record_to_json)
from repro.region.gateway import RegionGateway  # noqa: E402
from repro.region.transport import LoopbackTransport  # noqa: E402
from repro.router.gateway import FleetGateway  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402


def _setup(arch="smollm-135m", seed=0):
    cfg = get_config(arch, reduced=True)
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(seed))
    return cfg, m, params


def _request(cfg, rng, rid, plen=8, max_new=6):
    return Request(rid=rid, prompt=rng.integers(0, cfg.vocab, plen),
                   max_new=max_new)


def _clone(req):
    return Request(rid=req.rid, prompt=req.prompt.copy(),
                   max_new=req.max_new, extras=dict(req.extras))


def _get(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read()


# ---------------------------------------------------------------------------
# burn-rate math (no serving stack involved)
# ---------------------------------------------------------------------------

def test_objective_and_monitor_validation():
    with pytest.raises(ValueError):
        Objective("x", target=1.0)
    with pytest.raises(ValueError):
        Objective("x", target=0.0)
    assert Objective("x", target=0.9).budget == pytest.approx(0.1)
    with pytest.raises(ValueError):
        SLOMonitor([])
    with pytest.raises(ValueError):
        SLOMonitor([Objective("x")], fast_window=5, slow_window=3)
    with pytest.raises(ValueError):
        SLOMonitor([Objective("x"), Objective("x")])


def test_observe_needs_threshold_and_ignores_unknown():
    mon = SLOMonitor([Objective("avail", target=0.9)])
    with pytest.raises(ValueError):
        mon.observe("avail", 1.0)          # bool-fed objective
    mon.observe("nope", 1.0)               # unknown: silently ignored
    mon.observe_ok("nope", False)
    assert mon.counts("avail") == (0, 0)
    assert mon.wants("avail") and not mon.wants("nope")


def test_burn_rate_is_bad_fraction_over_budget():
    mon = SLOMonitor([Objective("lat", target=0.9, threshold=1.0)],
                     fast_window=4, slow_window=8)
    # tick 1: 9 good, 1 bad -> bad fraction 0.1 == budget -> burn 1.0
    for _ in range(9):
        mon.observe("lat", 0.5)
    mon.observe("lat", 2.0)
    assert mon.evaluate(1) == []
    fast, slow = mon.burn_rates("lat")
    assert fast == pytest.approx(1.0) and slow == pytest.approx(1.0)
    # tick 2: 5 more bad -> window burn well above any sane threshold
    for _ in range(5):
        mon.observe("lat", 2.0)
    mon.evaluate(2)
    fast, _ = mon.burn_rates("lat")
    assert fast == pytest.approx(((6 / 15) / 0.1))


def test_empty_window_burns_zero():
    mon = SLOMonitor([Objective("a", target=0.9)], fast_window=2,
                     slow_window=4)
    assert mon.burn_rates("a") == (0.0, 0.0)     # never evaluated
    mon.observe_ok("a", False)
    mon.evaluate(1)
    assert mon.burn_rates("a")[0] > 0
    for t in range(2, 5):
        mon.evaluate(t)                          # no traffic: fast ages out
    fast, slow = mon.burn_rates("a")
    assert fast == 0.0 and slow > 0              # slow still remembers
    for t in range(5, 9):
        mon.evaluate(t)
    assert mon.burn_rates("a") == (0.0, 0.0)     # now both aged out


def test_multiwindow_fire_and_clear_by_aging():
    """Fast+slow must both exceed the threshold to fire; the clear needs
    only the fast window to recover (here: by aging out, no new events)."""
    mon = SLOMonitor([Objective("a", target=0.9)], fast_window=2,
                     slow_window=6, burn_threshold=2.0)
    mon.observe_ok("a", False)
    out = mon.evaluate(1)
    assert [a.state for a in out] == ["firing"]
    assert isinstance(out[0], Alert) and out[0].objective == "a"
    assert out[0].tick == 1 and out[0].burn_fast > 2.0
    assert mon.evaluate(2) == []                 # still firing: no repeat
    assert "a" in mon.active
    cleared = None
    for t in range(3, 10):
        got = mon.evaluate(t)
        if got:
            cleared = got[0]
            break
    assert cleared is not None and cleared.state == "cleared"
    assert cleared.tick == 3                     # fast window aged out
    assert mon.active == {}
    aj = mon.alerts_json()
    assert [a["state"] for a in aj["history"]] == ["firing", "cleared"]
    assert aj["active"] == []
    assert aj["fast_window"] == 2 and aj["burn_threshold"] == 2.0


def test_slow_window_gates_noise():
    """One bad burst that the slow window dilutes must NOT fire — the
    multi-window shape exists to suppress exactly this page."""
    mon = SLOMonitor([Objective("a", target=0.9)], fast_window=2,
                     slow_window=8, burn_threshold=1.5)
    for t in range(1, 7):                        # 6 ticks of good traffic
        for _ in range(10):
            mon.observe_ok("a", True)
        mon.evaluate(t)
    for _ in range(4):                           # short 100%-bad burst
        mon.observe_ok("a", False)
    mon.evaluate(7)
    fast, slow = mon.burn_rates("a")
    assert fast > 1.5 > slow                     # fast alone is not enough
    assert mon.active == {}


def test_attach_obs_counts_and_instants():
    reg = MetricRegistry()
    tr = SpanTracer("t")
    mon = SLOMonitor([Objective("a", target=0.9)], fast_window=2,
                     slow_window=4, burn_threshold=2.0)
    mon.attach_obs(tr, reg, name="fleet0/slo")
    mon.observe_ok("a", False)
    tr.set_tick(1)
    mon.evaluate(1)
    for t in range(2, 6):
        mon.evaluate(t)
    txt = reg.prometheus_text()
    assert ('slo_alerts_total{monitor="fleet0/slo",objective="a",'
            'state="firing"} 1') in txt
    assert ('slo_alerts_total{monitor="fleet0/slo",objective="a",'
            'state="cleared"} 1') in txt
    inst = [e for e in tr.events if e["ph"] == "i"]
    assert [e["name"] for e in inst] == ["slo-firing", "slo-cleared"]
    assert all(e["track"] == "fleet0/slo" for e in inst)
    assert inst[0]["args"]["tick"] == 1


# ---------------------------------------------------------------------------
# the headline lifecycle: crash fires a TTFT-burn alert, recovery clears it
# ---------------------------------------------------------------------------

def test_crash_fires_ttft_burn_alert_then_clears():
    """A seeded replica crash destroys in-flight prefill work; the
    resubmitted requests' first tokens arrive pumps late, the ttft_pumps
    burn rate blows through both windows, and the alert fires — then
    clears once the bad events age out of the fast window.  Every tick is
    deterministic, and the lifecycle is visible three ways at once: the
    Alert records (served over real TCP), the tracer's SLO track, and the
    slo_alerts_total counters."""
    cfg, m, params = _setup()
    rng = np.random.default_rng(5)
    reqs = [_request(cfg, rng, rid) for rid in range(4)]

    inj = FaultInjector(0).crash(1, at_step=1, restart_at=8)
    gw = FleetGateway([ServeEngine(m, params, max_batch=4, max_seq=48)
                       for _ in range(2)],
                      transport=LoopbackTransport(), injector=inj,
                      heartbeat_timeout=2.0)
    reg = MetricRegistry()
    tr = SpanTracer("fleet")
    gw.attach_obs(tr, reg, name="fleet0")
    mon = SLOMonitor([Objective("ttft_pumps", target=0.75, threshold=2.0)],
                     fast_window=5, slow_window=15, burn_threshold=1.5)
    gw.attach_slo(mon)
    for r in reqs:
        gw.submit(_clone(r))
    for _ in range(14):
        gw.pump()

    # -- lifecycle: fire at the late first tokens, clear by window aging
    states = [(a.state, a.tick) for a in mon.alerts]
    assert states == [("firing", 3), ("cleared", 8)]
    assert mon.active == {}
    good, bad = mon.counts("ttft_pumps")
    assert (good, bad) == (2, 2)        # replica 0's ttfts on time, 1's late
    firing = mon.alerts[0]
    assert firing.burn_fast > 1.5 and firing.burn_slow > 1.5

    # -- the same lifecycle over a real TCP socket
    with ObsServer(registry=reg, slo=mon, tracer=tr) as srv:
        status, ctype, body = _get(srv.url + "/alerts")
        assert status == 200 and ctype == "application/json"
        assert json.loads(body) == json.loads(
            json.dumps(mon.alerts_json(), sort_keys=True))

    # -- tracer SLO track carries the transitions with their ticks
    slo_inst = [e for e in tr.events
                if e["ph"] == "i" and e["track"] == "fleet0/slo"]
    assert [(e["name"], e["args"]["tick"]) for e in slo_inst] == [
        ("slo-firing", 3), ("slo-cleared", 8)]

    # -- counters
    txt = reg.prometheus_text()
    assert ('slo_alerts_total{monitor="fleet0/slo",objective="ttft_pumps",'
            'state="firing"} 1') in txt
    assert ('slo_alerts_total{monitor="fleet0/slo",objective="ttft_pumps",'
            'state="cleared"} 1') in txt

    # -- and the crash victims still finish (recovery, not loss)
    gw.run_until_drained(400)
    for r in reqs:
        assert gw.handle(r.rid).done


def test_partition_fires_wan_delivery_alert_then_clears():
    """A region draining a browned-out fleet into a partitioned WAN link
    fails every ship; wan_delivery burn fires, parked sessions re-drain
    each pump until the partition heals, then the alert clears and the
    sessions actually land."""
    cfg, m, params = _setup()
    rng = np.random.default_rng(4)
    reqs = [_request(cfg, rng, rid, plen=7, max_new=40) for rid in range(3)]

    inj = FaultInjector(3).partition(0, 1, start=0, until=12)
    transport = ChaosTransport(LoopbackTransport(), inj)
    fleets = [FleetGateway([ServeEngine(m, params, max_batch=4, max_seq=64)
                            for _ in range(2)]) for _ in range(2)]
    region = RegionGateway(fleets, transport=transport)
    mon = SLOMonitor([Objective("wan_delivery", target=0.9)],
                     fast_window=4, slow_window=12, burn_threshold=2.0)
    region.attach_slo(mon)
    for r in reqs:
        region.submit(_clone(r), origin=0)
    for _ in range(2):
        region.pump()
        inj.advance()                 # region pumps don't own the fault clock
    region.brownout(0)
    for _ in range(28):
        region.pump()
        inj.advance()

    states = [(a.state, a.tick) for a in mon.alerts]
    assert states == [("firing", 3), ("cleared", 16)]
    assert mon.active == {}
    good, bad = mon.counts("wan_delivery")
    assert bad >= 10 and good >= 1    # failed all through the partition,
    st = region.stats()               # then the parked sessions landed
    assert st["delivery_failures"] >= 10 and st["wan_ships"] >= 1
    region.run_until_drained(600)
    for r in reqs:
        assert region.request(r.rid).done


# ---------------------------------------------------------------------------
# live endpoint: byte-diff against the in-process sources
# ---------------------------------------------------------------------------

def test_server_serves_every_endpoint_over_tcp():
    from benchmarks.fleet_routing import simulate

    reg = MetricRegistry()
    reg.counter("demo_total", "d", fleet="g0").inc(3)
    reg.histogram("demo_seconds", "d", fleet="g0").observe(0.004)
    tss = TimeSeriesStore(reg, cap=8)
    tss.sample(1, 0.5)
    tr = SpanTracer("srv")
    tr.set_tick(2)
    tr.instant("hello", None, "main", k=1)
    mon = SLOMonitor([Objective("a", target=0.9)], fast_window=2,
                     slow_window=4)
    mon.observe_ok("a", False)
    mon.evaluate(1)
    log = DecisionLog()
    simulate("ptt", n_requests=20, seed=0, attribution=log)
    assert len(log) > 0

    with ObsServer(registry=reg, timeseries=tss, slo=mon, tracer=tr,
                   decisions=log) as srv:
        # /metrics is the prometheus exposition, byte for byte
        status, ctype, body = _get(srv.url + "/metrics")
        assert status == 200
        assert ctype.startswith("text/plain")
        assert body.decode() == reg.prometheus_text()

        # JSON endpoints mirror their in-process sources exactly
        for path, src in [("/timeseries", tss.export()),
                          ("/alerts", mon.alerts_json()),
                          ("/traces", tr.chrome_trace())]:
            status, ctype, body = _get(srv.url + path)
            assert status == 200 and ctype == "application/json"
            assert json.loads(body) == json.loads(
                json.dumps(src, sort_keys=True))

        # /debug/decisions mirrors the DecisionLog, with filters
        status, _, body = _get(srv.url + "/debug/decisions")
        doc = json.loads(body)
        assert doc["count"] == len(log)
        want = json.loads(json.dumps(
            [record_to_json(r) for r in log.records], sort_keys=True,
            default=lambda o: o.item()))
        assert doc["records"] == want
        _, _, body = _get(srv.url + "/debug/decisions?kind=route&n=3")
        doc3 = json.loads(body)
        assert doc3["count"] == 3 and doc3["records"] == want[-3:]
        _, _, body = _get(srv.url + "/debug/decisions?kind=nope")
        assert json.loads(body)["count"] == 0

        # index lists everything; unknown paths 404 with the same list
        _, _, body = _get(srv.url + "/")
        assert json.loads(body)["endpoints"] == [
            "/metrics", "/timeseries", "/alerts", "/traces",
            "/debug/decisions"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/nope")
        assert ei.value.code == 404
        assert json.loads(ei.value.read())["endpoints"][0] == "/metrics"
    # after stop() the socket is really gone
    with pytest.raises(Exception):
        _get(srv.url + "/metrics")


def test_server_404s_missing_collaborators():
    reg = MetricRegistry()
    with ObsServer(registry=reg) as srv:
        status, _, _ = _get(srv.url + "/metrics")
        assert status == 200
        for path in ("/timeseries", "/alerts", "/traces",
                     "/debug/decisions"):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _get(srv.url + path)
            assert ei.value.code == 404


def test_server_rejects_double_start():
    srv = ObsServer(registry=MetricRegistry()).start()
    try:
        with pytest.raises(RuntimeError):
            srv.start()
    finally:
        srv.stop()
    srv.stop()                          # idempotent


# ---------------------------------------------------------------------------
# trace ticks: instants join time-series samples on the pump clock
# ---------------------------------------------------------------------------

def test_instants_carry_pump_tick_joining_timeseries():
    """Chaos-delayed delivery skews wall timestamps, but every instant a
    gateway emits carries the monotonic pump tick it happened on — the
    same tick the TimeSeriesStore stamps its samples with, so the two
    artifacts join on one logical clock regardless of wall time."""
    cfg, m, params = _setup()
    rng = np.random.default_rng(5)

    inj = FaultInjector(0).crash(1, at_step=1, restart_at=8)
    gw = FleetGateway([ServeEngine(m, params, max_batch=4, max_seq=48)
                       for _ in range(2)],
                      transport=LoopbackTransport(), injector=inj,
                      heartbeat_timeout=2.0)
    reg = MetricRegistry()
    tr = SpanTracer("fleet")
    gw.attach_obs(tr, reg, name="fleet0")
    tss = TimeSeriesStore(reg, cap=64)
    gw.attach_timeseries(tss)
    for rid in range(4):
        gw.submit(_request(cfg, rng, rid))
    for _ in range(10):
        gw.pump()
    gw.run_until_drained(400)

    inst = [e for e in tr.events if e["ph"] == "i"]
    assert inst, "expected instants (admit/crash/resubmit) under chaos"
    # every instant emitted during a pump carries that pump's tick;
    # submit-time instants (admit) precede pump 1 and carry None
    ticks = [e["tick"] for e in inst if e["tick"] is not None]
    assert ticks and ticks == sorted(ticks)       # monotonic pump clock
    sampled = {p[0] for p in tss.points("fleet_replica_quarantined",
                                        fleet="fleet0", replica=1)}
    assert set(ticks) <= sampled                  # every instant joins a
    #                                               time-series sample row
    # chrome export surfaces the tick as args.pump_tick on instants only
    ev = [e for e in tr.chrome_trace()["traceEvents"]
          if e["ph"] == "i" and "pump_tick" in e.get("args", {})]
    assert [e["args"]["pump_tick"] for e in ev] == ticks

    # submit-time admits precede pump 1 and carry no tick; every finish
    # happens inside a pump and carries its tick — the tick, not the
    # chaos-skewed wall ts, says which pump a request really ended on
    admits = [e for e in inst if e["name"] == "admit"]
    assert admits and all(e["tick"] is None for e in admits)
    finishes = [e for e in inst if e["name"] == "finish"]
    assert finishes and all(e["tick"] is not None for e in finishes)
    # the crash victims' finishes land pumps after the survivors' — the
    # tick gap is the recovery cost, legible straight off the trace
    assert min(e["tick"] for e in finishes) < max(e["tick"]
                                                  for e in finishes)
