"""Telemetry plane acceptance: metric registry exposition (golden-file),
Chrome trace-event export (golden-file + schema), decision-attribution
additivity at the TraceTable and in the fleet benchmark, the unified
``stats()`` counter names across all three scales, and the headline
span-tracer property — a live-migrated request keeps ONE causal timeline
spanning both replicas.

Regenerate the golden fixtures after an intentional format change with

    PYTHONPATH=src python tests/test_obs.py --regen
"""

import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.common import percentile  # noqa: E402
from repro.core.tracetable import (Candidate, Latency, Occupancy,  # noqa: E402
                                   SearchContext, TraceTable)
from repro.obs import (BYTE_BUCKETS, CANONICAL_STATS, DecisionLog,  # noqa: E402
                       Histogram, MetricRegistry, NULL_TRACER, SpanTracer)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------

def test_counter_only_goes_up():
    reg = MetricRegistry()
    c = reg.counter("fleet_requests_served_total", "served")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_get_or_create_returns_the_live_child():
    reg = MetricRegistry()
    a = reg.counter("serve_decode_tokens_total", "tokens", engine="r0")
    b = reg.counter("serve_decode_tokens_total", "tokens", engine="r0")
    assert a is b                        # instrumented code holds the child
    other = reg.counter("serve_decode_tokens_total", "tokens", engine="r1")
    assert other is not a                # distinct label set, distinct series


def test_registry_rejects_kind_mismatch_and_bad_names():
    reg = MetricRegistry()
    reg.counter("fleet_ttft_seconds")
    with pytest.raises(ValueError):
        reg.histogram("fleet_ttft_seconds")       # already a counter
    with pytest.raises(ValueError):
        reg.counter("bad-metric-name")
    with pytest.raises(ValueError):
        reg.gauge("ok_name", **{"bad-label": 1})


def test_histogram_percentile_brackets_the_exact_value():
    """The histogram answers percentiles at bucket resolution: its answer
    is a bucket upper bound that covers (and stays within one bucket step
    of) the exact percentile computed from the raw samples by the shared
    ``benchmarks.common.percentile`` helper."""
    samples = [0.002] * 51 + [0.02] * 30 + [0.2] * 15 + [2.0] * 5
    h = Histogram()
    for v in samples:
        h.observe(v)
    assert h.count == len(samples)
    assert h.sum == pytest.approx(sum(samples))
    for q in (50, 90, 99):
        exact = percentile(samples, q)
        bound = h.percentile(q)
        assert bound in h.buckets
        assert exact <= bound <= 1.3 * exact, (q, exact, bound)
    assert Histogram().percentile(50) == 0.0     # empty histogram


def _filled_registry() -> MetricRegistry:
    """Deterministic fill exercising every family kind, multiple series
    per family, both bucket lists, and overflow (+Inf) samples."""
    reg = MetricRegistry()
    c = reg.counter("fleet_requests_served_total",
                    "Requests finished fleet-wide", fleet="fleet")
    c.inc()
    c.inc(2)
    reg.counter("fleet_requests_served_total",
                "Requests finished fleet-wide", fleet="west").inc(5)
    reg.gauge("serve_utilization", "Batch-slot occupancy",
              engine="fleet/r0").set(0.25)
    h = reg.histogram("fleet_ttft_seconds", "Client-facing TTFT",
                      fleet="fleet")
    for v in (0.0004, 0.003, 0.003, 0.08, 0.7, 42.0):   # 42 -> +Inf slot
        h.observe(v)
    reg.histogram("region_ship_bytes", "Session wire payload",
                  buckets=BYTE_BUCKETS, region="region").observe(2048.0)
    return reg


def test_prometheus_text_matches_golden():
    text = _filled_registry().prometheus_text()
    with open(os.path.join(GOLDEN, "metrics.prom")) as f:
        assert text == f.read()


def test_prometheus_histogram_buckets_are_cumulative():
    text = _filled_registry().prometheus_text()
    counts = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
              if line.startswith("fleet_ttft_seconds_bucket")]
    assert counts == sorted(counts)              # le-buckets never decrease
    assert counts[-1] == 6                       # +Inf covers every sample


def test_snapshot_is_json_able_and_consistent():
    snap = _filled_registry().snapshot()
    snap2 = json.loads(json.dumps(snap))         # round-trips losslessly
    assert snap2 == snap
    ttft = snap["fleet_ttft_seconds"]["series"][0]
    assert sum(ttft["bucket_counts"]) == ttft["count"] == 6
    assert len(ttft["bucket_counts"]) == len(ttft["buckets"]) + 1


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def _scripted_tracer() -> SpanTracer:
    """A deterministic-clock tracer replaying a migrated request's life:
    admit -> prefill -> decode on r0 -> migrate -> decode on r1 -> finish,
    plus a WAN ship span on the region track."""
    state = {"now": 0.0}

    def clock() -> float:
        state["now"] = round(state["now"] + 0.001, 6)
        return state["now"]

    tr = SpanTracer(name="fleet", clock=clock)
    tid = tr.trace_for(7)
    assert tid == "fleet/r7"
    tr.instant("admit", tid, "fleet", replica=0)
    tr.complete("prefill", tid, "fleet/r0", ts=0.002, dur=0.004,
                prompt_len=8)
    tr.complete("decode-chunk", tid, "fleet/r0", ts=0.007, dur=0.006,
                tokens=4)
    tr.instant("migrate-out", tid, "fleet/r0")
    with tr.span("wan-ship", tid, "region", src=0, dst=1):
        pass
    tr.adopt(7, tid)                   # the importing side re-binds rid 7
    tr.instant("migrate-in", tid, "fleet/r1")
    tr.complete("decode-chunk", tid, "fleet/r1", ts=0.020, dur=0.005,
                tokens=4)
    tr.instant("finish", tid, "fleet/r1")
    return tr


def test_chrome_trace_matches_golden():
    rendered = json.dumps(_scripted_tracer().chrome_trace(), indent=1,
                          sort_keys=True)
    with open(os.path.join(GOLDEN, "trace.json")) as f:
        assert rendered == f.read()


def test_chrome_trace_schema():
    """Structural contract of the export: valid JSON, only X/i/M phases,
    non-negative monotone timestamps, durations on spans, and every
    pid/tid named by a metadata event."""
    ct = json.loads(json.dumps(_scripted_tracer().chrome_trace()))
    events = ct["traceEvents"]
    assert events and ct["displayTimeUnit"] == "ms"
    assert {e["ph"] for e in events} <= {"X", "i", "M"}
    data = [e for e in events if e["ph"] != "M"]
    ts = [e["ts"] for e in data]
    assert ts == sorted(ts) and ts[0] == 0.0     # relative to first event
    for e in data:
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        else:
            assert e["s"] == "t"
    named_pids = {e["pid"] for e in events
                  if e["ph"] == "M" and e["name"] == "process_name"}
    named_tids = {e["tid"] for e in events
                  if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {e["pid"] for e in data} <= named_pids
    assert {e["tid"] for e in data} <= named_tids


def test_tracer_timeline_and_tracks_follow_one_trace():
    tr = _scripted_tracer()
    tl = tr.timeline("fleet/r7")
    assert [e["ts"] for e in tl] == sorted(e["ts"] for e in tl)
    assert tr.tracks("fleet/r7") == ["fleet", "fleet/r0", "region",
                                     "fleet/r1"]
    assert tr.timeline("no-such-trace") == []


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.trace_for(3) is None
    NULL_TRACER.instant("x")
    NULL_TRACER.complete("x", ts=0.0, dur=1.0)
    with NULL_TRACER.span("x"):
        pass                                     # no state, no events


def test_tracer_event_cap_evicts_oldest():
    tr = SpanTracer(name="t", cap=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr.events) == 4
    assert [e["name"] for e in tr.events] == ["e6", "e7", "e8", "e9"]


# ---------------------------------------------------------------------------
# decision attribution
# ---------------------------------------------------------------------------

def test_search_attribution_terms_sum_to_total():
    """The additivity invariant at its source: a composed Sum cost scored
    through ``TraceTable.search`` yields per-term breakdowns summing to
    each candidate's total, with repeated model classes disambiguated."""
    t = TraceTable([3])
    for r, v in enumerate((2.0, 0.5, 1.0)):
        t.update((r,), v)
    got = []
    ctx = SearchContext(attribution=got.append)
    cost = Latency() + Occupancy() + Latency()   # Latency twice on purpose
    chosen = t.search([Candidate(key=(r,), item=r, width=2)
                       for r in range(3)], cost, ctx=ctx)
    assert chosen == 1                           # min 3*value with width 2
    (sa,) = got
    assert sa.chosen == 1 and sa.policy == "GlobalSearch"
    assert len(sa.candidates) == 3
    for c in sa.candidates:
        assert set(c.terms) == {"Latency", "Occupancy", "Latency#2"}
        assert sum(c.terms.values()) == pytest.approx(c.total, abs=1e-12)
        assert c.terms["Occupancy"] == pytest.approx(2 * c.value)


def test_decision_log_hook_records_and_annotates():
    t = TraceTable([2])
    t.update((0,), 1.0)
    t.update((1,), 3.0)
    log = DecisionLog()
    hook = log.hook("route", lambda sa: {c.item: {"v": c.value}
                                         for c in sa.candidates},
                    req_class="DECODE")
    recbox = []
    ctx = SearchContext(attribution=lambda sa: recbox.append(hook(sa)))
    t.search([Candidate(key=(r,), item=r) for r in range(2)],
             Latency(), ctx=ctx)
    rec = recbox[0]
    rec.meta.update(replica=rec.chosen, action="ADMIT")  # post-hoc annotate
    assert log.last("route") is rec and log.last("nope") is None
    assert rec.check()
    assert rec.chosen == 0 and rec.rows[1] == {"v": 3.0}
    assert rec.breakdown() == {"Latency": 1.0}
    with pytest.raises(KeyError):
        rec.candidate(99)
    text = DecisionLog.explain(rec)
    assert "chose 0" in text and "Latency=" in text and "ADMIT" in text


def test_fleet_benchmark_every_decision_carries_a_valid_breakdown():
    """ISSUE acceptance: run the fleet routing benchmark with a
    DecisionLog attached — every routing decision must land there with a
    per-term cost breakdown summing to each candidate's total, and the
    final post-admission outcome annotated."""
    from benchmarks.fleet_routing import N_REPLICAS, simulate

    log = DecisionLog()
    res = simulate("ptt", n_requests=300, seed=0, attribution=log)
    assert res["n"] > 0 and len(log) > 100       # one record per search
    assert {r.kind for r in log.records} == {"route"}
    for rec in log.records:
        assert rec.check(), DecisionLog.explain(rec)
        assert rec.meta["action"] in ("ADMIT", "QUEUE", "SHED")
        assert rec.meta["replica"] in range(N_REPLICAS)
        assert set(rec.rows) == {c.item for c in rec.search.candidates}
    admitted = [r for r in log.records if r.meta["action"] == "ADMIT"]
    # the annotated final pick is a real candidate of the search (overflow
    # may legally override the search's own chosen item)
    for rec in admitted[:50]:
        assert rec.candidate(rec.meta["replica"]).terms


# ---------------------------------------------------------------------------
# unified stats() facades
# ---------------------------------------------------------------------------

class _NullModel:
    """ServeEngine.__init__ only reads the jitted decode handles; a stats
    facade test never steps the engine, so None handles suffice."""
    decode_jit = None
    decode_fused = None


def test_stats_facades_share_canonical_keys_with_legacy_aliases():
    from repro.region import RegionGateway
    from repro.router import FleetGateway
    from repro.serve import ServeEngine

    engines = [ServeEngine(_NullModel(), None, max_batch=2, max_seq=8)
               for _ in range(2)]
    gw = FleetGateway(engines)
    region = RegionGateway([gw])
    scales = {"engine": engines[0].stats(), "fleet": gw.stats(),
              "region": region.stats()}
    for name, s in scales.items():
        for key in CANONICAL_STATS:
            assert key in s, (name, key)
            assert isinstance(s[key], (int, float)), (name, key)
    # legacy aliases stay and agree with the canonical counters
    e, f, r = scales["engine"], scales["fleet"], scales["region"]
    assert e["sessions_migrated"] == (e["sessions_exported"]
                                      + e["sessions_imported"])
    assert f["served"] == f["requests_served"]
    assert f["migrations"] == f["sessions_migrated"]
    assert r["wan_ships"] == r["sessions_migrated"]
    assert r["requests_served"] == f["requests_served"]


# ---------------------------------------------------------------------------
# end-to-end: a migrated request keeps ONE timeline (real engines)
# ---------------------------------------------------------------------------

def test_migrated_request_keeps_one_causal_timeline():
    """ISSUE acceptance: quarantine-drain a live decode session between
    two real engines under one shared tracer; the request's exported trace
    must be a single trace id whose timeline runs contiguously from the
    source replica through migrate-out/migrate-in to the destination."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.router import FleetGateway
    from repro.serve import Request, ServeEngine

    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    engines = [ServeEngine(m, params, max_batch=2, max_seq=48)
               for _ in range(2)]
    gw = FleetGateway(engines)
    tracer, registry = SpanTracer(name="fleet"), MetricRegistry()
    gw.attach_obs(tracer, registry, name="fleet")
    assert engines[0].tracer is tracer           # propagated downward
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6), max_new=12)
            for i in range(4)]
    for r in reqs:
        gw.submit(r)
    for _ in range(3):
        gw.pump()
    victim = max(range(2), key=lambda i: engines[i].active_count())
    gw.router.detector.force_quarantine(victim)
    gw.pump()
    gw.run_until_drained(max_steps=1000)
    assert all(r.done for r in reqs)
    assert gw.stats()["sessions_migrated"] >= 1

    moved = [r.rid for r in reqs
             if any(e["name"] == "migrate-out"
                    for e in tracer.timeline(tracer.trace_for(r.rid)))]
    assert moved, "no traced request migrated"
    tid = tracer.trace_for(moved[0])
    src, dst = f"fleet/r{victim}", f"fleet/r{1 - victim}"
    tracks = tracer.tracks(tid)
    assert src in tracks and dst in tracks       # both replicas, one trace
    tl = tracer.timeline(tid)
    names = [e["name"] for e in tl]
    out_i, in_i = names.index("migrate-out"), names.index("migrate-in")
    assert out_i < in_i < names.index("finish")
    # contiguity: decode work on the source strictly precedes the handoff,
    # decode work on the destination strictly follows it — one causal line
    assert any(e["name"] == "decode-chunk" and e["track"] == src
               for e in tl[:out_i])
    assert any(e["name"] == "decode-chunk" and e["track"] == dst
               for e in tl[in_i:])
    assert not any(e["track"] == src for e in tl[in_i:])
    assert "prefill" in names                    # admission span survived

    # the exported view keeps the request as ONE process (pid)
    ct = tracer.chrome_trace()
    pid = {e["args"]["name"]: e["pid"] for e in ct["traceEvents"]
           if e.get("ph") == "M" and e["name"] == "process_name"}[tid]
    own = [e for e in ct["traceEvents"]
           if e.get("pid") == pid and e["ph"] != "M"]
    assert {"migrate-out", "migrate-in"} <= {e["name"] for e in own}

    # the attached registry saw the migration on both engine facades
    snap = registry.snapshot()
    exports = {s["labels"]["engine"]: s["value"]
               for s in snap["serve_sessions_exported_total"]["series"]}
    assert exports[src] >= 1
    assert snap["fleet_sessions_migrated_total"]["series"][0]["value"] >= 1
    assert snap["serve_decode_step_seconds"]["series"][0]["count"] > 0


# ---------------------------------------------------------------------------
# golden regeneration
# ---------------------------------------------------------------------------

if __name__ == "__main__" and "--regen" in sys.argv:
    os.makedirs(GOLDEN, exist_ok=True)
    with open(os.path.join(GOLDEN, "metrics.prom"), "w") as f:
        f.write(_filled_registry().prometheus_text())
    with open(os.path.join(GOLDEN, "trace.json"), "w") as f:
        f.write(json.dumps(_scripted_tracer().chrome_trace(), indent=1,
                           sort_keys=True))
    print(f"regenerated golden fixtures under {GOLDEN}")
