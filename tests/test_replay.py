"""Decision-replay regression tooling: JSONL persistence round-trips the
DecisionLog exactly, an identity replay reproduces every recorded total
and winner bit-for-bit (capture is faithful), and a modified cost model
reports per-term deltas + flipped winners as a deterministic diff.

The committed fixture is 60 routing decisions from the seeded fleet
benchmark.  Regenerate after an intentional capture-format change with

    PYTHONPATH=src python tests/test_replay.py --regen
"""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))

from repro.core.tracetable import QueueAware, Sum  # noqa: E402
from repro.obs import DecisionLog  # noqa: E402
from repro.obs.replay import (dump_jsonl, load_jsonl, main,  # noqa: E402
                              parse_cost, record_to_json, replay, rescore)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "decisions",
                       "route_log.jsonl")


def _fresh_log():
    from benchmarks.fleet_routing import simulate

    log = DecisionLog()
    simulate("ptt", n_requests=60, seed=0, attribution=log)
    return log


def _records():
    return load_jsonl(FIXTURE)


def _identity_cost(rec):
    """The cost model each recorded search actually ran under: route
    searches (metric 0) score queue pressure in seconds-per-token, the
    sticky re-place search (metric 1) in raw backlog tokens."""
    if rec["context"]["metric"] == 0:
        return parse_cost("queueaware")
    return parse_cost("queueaware:value_per_token=false")


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_fixture_matches_regenerated_log(tmp_path):
    """The committed JSONL is byte-reproducible from the seeded benchmark
    — drift here means capture or serialization changed shape and the
    fixture needs a --regen (and downstream consumers a look)."""
    log = _fresh_log()
    out = tmp_path / "log.jsonl"
    assert dump_jsonl(log, str(out)) == 60
    assert out.read_text() == open(FIXTURE).read()


def test_roundtrip_preserves_every_field(tmp_path):
    log = _fresh_log()
    out = tmp_path / "log.jsonl"
    dump_jsonl(log, str(out))
    loaded = load_jsonl(str(out))
    assert len(loaded) == len(log.records)
    for rec, got in zip(log.records, loaded):
        want = json.loads(json.dumps(record_to_json(rec), sort_keys=True,
                                     default=lambda o: o.item()))
        assert got == want


def test_fixture_shape():
    recs = _records()
    assert len(recs) == 60
    assert {r["kind"] for r in recs} == {"route"}
    metrics = sorted({r["context"]["metric"] for r in recs})
    assert metrics == [0, 1]          # route searches + sticky re-places
    for r in recs:
        assert r["candidates"] and r["chosen"] is not None
        per_item = r["context"]["per_item"]
        assert len(per_item) == len(r["candidates"])
        for c, pi in zip(r["candidates"], per_item):
            # additivity survives the round trip
            assert sum(c["terms"].values()) == pytest.approx(c["total"])
            assert "backlog" in pi and "service" in pi


# ---------------------------------------------------------------------------
# identity replay: capture is faithful
# ---------------------------------------------------------------------------

def test_identity_rescore_reproduces_recorded_totals():
    """Re-scoring under the cost model the search originally ran with
    must reproduce every candidate total and every winner exactly — the
    captured context really is sufficient to re-run the decision."""
    for rec in _records():
        out = rescore(rec, _identity_cost(rec))
        assert not out["flipped"]
        for c in out["candidates"]:
            assert c["total"] == pytest.approx(c["old_total"], abs=1e-12)
            assert c["terms"] == pytest.approx(c["old_terms"])


def test_policy_overrides_are_not_flips():
    """Sticky decisions where the live policy kept the session home
    despite a cheaper candidate are overrides, never identity flips."""
    recs = _records()
    rep = replay(recs, parse_cost("queueaware"),
                 kinds=["route"])
    # identity cost for metric-0 records; metric-1 records rescored under
    # the wrong units may flip, so count overrides on the full replay of
    # the correctly-matched models instead:
    overrides = 0
    for rec in recs:
        out = rescore(rec, _identity_cost(rec))
        assert out["old_winner"] == out["new_winner"]
        if out["policy_override"]:
            assert rec["chosen"] != out["old_winner"]
            overrides += 1
    assert overrides == 5             # sticky stay-home decisions
    assert rep.n == 60


# ---------------------------------------------------------------------------
# modified cost: the regression diff
# ---------------------------------------------------------------------------

def test_modified_cost_reports_flips_and_term_deltas():
    recs = _records()
    rep = replay(recs,
                 parse_cost("queueaware+migration:fixed=0.5,per_token=0.001"))
    assert rep.n == 60 and rep.kinds == {"route": 60}
    assert len(rep.flips) == 8
    assert rep.policy_overrides == 5
    tt = rep.term_totals
    assert set(tt) == {"QueueAware", "MigrationCost"}
    assert tt["MigrationCost"]["old"] == 0.0          # not in the old model
    assert tt["MigrationCost"]["delta"] == pytest.approx(47.376, abs=0.01)
    assert tt["QueueAware"]["delta"] == pytest.approx(322.434, abs=0.01)
    for fl in rep.flips:
        rec = recs[fl["index"]]
        items = {c["item"] for c in rec["candidates"]}
        assert fl["old"] in items and fl["new"] in items and \
            fl["old"] != fl["new"]
    # report renders and serializes
    txt = rep.render()
    assert "replayed 60 decisions" in txt and "8 flipped winner(s)" in txt
    assert "term MigrationCost" in txt
    doc = json.loads(json.dumps(rep.to_json()))
    assert doc["n"] == 60 and len(doc["flips"]) == 8


def test_kind_filter():
    recs = _records()
    rep = replay(recs, parse_cost("queueaware"), kinds=["nope"])
    assert rep.n == 0 and rep.flips == [] and rep.term_totals == {}


# ---------------------------------------------------------------------------
# cost-spec grammar + CLI
# ---------------------------------------------------------------------------

def test_parse_cost_grammar():
    c = parse_cost("queueaware")
    assert isinstance(c, QueueAware) and c.value_per_token
    c = parse_cost("queueaware:value_per_token=false")
    assert not c.value_per_token
    c = parse_cost("queueaware+migration:fixed=0.05,per_token=2e-6")
    assert isinstance(c, Sum) and len(c.parts) == 2
    assert c.parts[1].fixed == pytest.approx(0.05)
    assert c.parts[1].per_token == pytest.approx(2e-6)
    with pytest.raises(ValueError):
        parse_cost("nope")
    with pytest.raises(ValueError):
        parse_cost("")


def test_cli_prints_report_and_writes_json(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = main([FIXTURE, "--cost",
               "queueaware+migration:fixed=0.5,per_token=0.001",
               "--kind", "route", "--json", str(out)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "replayed 60 decisions (route=60)" in text
    assert "8 flipped winner(s), 5 policy override(s)" in text
    doc = json.loads(out.read_text())
    assert doc["n"] == 60 and doc["policy_overrides"] == 5


# ---------------------------------------------------------------------------
# --regen entrypoint
# ---------------------------------------------------------------------------

if __name__ == "__main__" and "--regen" in sys.argv:
    n = dump_jsonl(_fresh_log(), FIXTURE)
    print(f"wrote {n} records to {FIXTURE}")
