"""End-to-end training integration: learning, restart determinism,
straggler rebalancing in the loop, gradient compression parity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_state_init


def _setup(compress=False, micro=1):
    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    opt = AdamWConfig(lr=5e-3, warmup_steps=3, total_steps=50)
    state, _ = train_state_init(m, jax.random.PRNGKey(0), opt,
                                compress_dcn=compress)
    step = jax.jit(make_train_step(m, opt, microbatches=micro,
                                   compress_dcn=compress))
    data = DataConfig(vocab=cfg.vocab, global_batch=8, seq_len=32, seed=1)
    return cfg, state, step, data


def _run(state, step, data, lo, hi):
    src = SyntheticLMData(data, start_step=lo)
    losses = []
    for i in range(lo, hi):
        b = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        state, metrics = step(state, b)
        losses.append(float(metrics["loss"]))
    src.close()
    return state, losses


def test_training_learns():
    _, state, step, data = _setup()
    _, losses = _run(state, step, data, 0, 30)
    assert losses[-1] < losses[0] - 1.0


def test_restart_determinism(tmp_path):
    """10 steps + checkpoint + 10 steps == 20 straight steps, bitwise on
    params (the fault-tolerance contract)."""
    _, state_a, step, data = _setup()
    state_a, _ = _run(state_a, step, data, 0, 20)

    _, state_b, step_b, _ = _setup()
    state_b, _ = _run(state_b, step_b, data, 0, 10)
    save_checkpoint(str(tmp_path), 10, state_b,
                    extra={"data": {"step": 10}})
    state_c, extra = load_checkpoint(str(tmp_path), 10, state_b)
    assert extra["data"]["step"] == 10
    state_c, _ = _run(state_c, step_b, data, 10, 20)

    for a, c in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_microbatched_grads_match_full_batch():
    """gradient accumulation over 4 microbatches == single big batch
    (loss average; params after 1 step nearly equal)."""
    _, s1, step1, data = _setup(micro=1)
    _, s4, step4, _ = _setup(micro=4)
    src = SyntheticLMData(data)
    b = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    src.close()
    s1, m1 = step1(s1, b)
    s4, m4 = step4(s4, b)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, c in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-4, atol=2e-5)


def test_compressed_training_converges():
    _, state, step, data = _setup(compress=True)
    _, losses = _run(state, step, data, 0, 30)
    assert losses[-1] < losses[0] - 1.0
