"""Property-based fuzzing of the RSES session wire format.

The chaos plane's reliability guarantees rest on one property: any
mutation of a wire payload — truncation, bit flips, header field
mutation, or arbitrary foreign bytes — is *detected* and surfaces as
:class:`WireFormatError`.  Never a crash with a different exception,
never a hang, never a successfully-decoded-but-wrong session, and (by
construction — the payload is msgpack) never an unpickle of attacker
bytes.  These tests drive that property with random mutations via
``hypothesis`` when installed, else the deterministic shim.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dep: deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.region.wire import (WIRE_COMPAT, WireFormatError, decode_session,
                               encode_session, verify_crc, wire_header)
from repro.serve.engine import Request, Session


def _payload(seed=0):
    rng = np.random.default_rng(seed)
    req = Request(rid=41, prompt=rng.integers(1, 1000, 9).astype(np.int32),
                  max_new=7, tenant="fuzz",
                  out_tokens=[3, 1, 4], t_first=1.25, t_admit=1.0)
    sess = Session(req=req, pos=12, cur_token=4,
                   cache={"k": rng.standard_normal((2, 12, 4)).astype(
                       np.float32),
                          "v": rng.standard_normal((2, 12, 4)).astype(
                       np.float32)},
                   trace={"trace_id": "f0/r1"}, prefilled=None,
                   delivery=(0, 41, 2))
    return encode_session(sess)


DATA = _payload()


def _expect_reject(mutated: bytes) -> None:
    """The only acceptable outcomes: WireFormatError, or a decode to a
    session equal to the original (the mutation hit a byte the codec
    doesn't distinguish — impossible under CRC unless unchanged)."""
    if mutated == DATA:
        return                       # identity mutation: nothing to detect
    with pytest.raises(WireFormatError):
        decode_session(mutated)


# -- deterministic edges -----------------------------------------------------

def test_roundtrip_is_clean():
    sess = decode_session(DATA)
    assert sess.req.rid == 41
    assert sess.delivery == (0, 41, 2)
    assert verify_crc(DATA)["version"] in WIRE_COMPAT


def test_empty_and_tiny_payloads():
    for n in range(12):              # anything shorter than the header
        with pytest.raises(WireFormatError):
            decode_session(DATA[:n])


def test_foreign_bytes():
    with pytest.raises(WireFormatError):
        decode_session(b"GET / HTTP/1.1\r\n\r\n" + bytes(64))
    with pytest.raises(WireFormatError):
        # pickle-looking bytes must be rejected at the magic check, long
        # before anything could interpret them
        decode_session(b"\x80\x04\x95" + DATA[3:])


# -- random truncation -------------------------------------------------------

@settings(max_examples=60)
@given(cut=st.integers(min_value=0, max_value=10_000))
def test_truncation_always_rejected(cut):
    n = cut % len(DATA)              # every prefix length, header included
    if n == len(DATA):
        return
    _expect_reject(DATA[:n])


# -- random bit flips --------------------------------------------------------

@settings(max_examples=120)
@given(bit=st.integers(min_value=0, max_value=2**31))
def test_single_bit_flip_always_rejected(bit):
    b = bit % (len(DATA) * 8)
    buf = bytearray(DATA)
    buf[b // 8] ^= 1 << (b % 8)
    _expect_reject(bytes(buf))


@settings(max_examples=40)
@given(bits=st.lists(st.integers(min_value=0, max_value=2**31),
                     min_size=2, max_size=16))
def test_multi_bit_flips_always_rejected(bits):
    buf = bytearray(DATA)
    for bit in bits:
        b = bit % (len(DATA) * 8)
        buf[b // 8] ^= 1 << (b % 8)
    _expect_reject(bytes(buf))


# -- header mutation ---------------------------------------------------------

@settings(max_examples=60)
@given(pos=st.integers(min_value=0, max_value=9),
       val=st.integers(min_value=0, max_value=255))
def test_header_byte_mutation_always_rejected(pos, val):
    """Every header byte — magic(0:4), version(4), codec(5), crc(6:10) —
    set to an arbitrary value either reproduces the original byte or is
    rejected; a corrupted version byte must never select a wrong-layout
    decode."""
    buf = bytearray(DATA)
    buf[pos] = val
    _expect_reject(bytes(buf))


@settings(max_examples=30)
@given(version=st.integers(min_value=0, max_value=255))
def test_unknown_versions_rejected_at_header(version):
    buf = bytearray(DATA)
    buf[4] = version
    if version in WIRE_COMPAT:
        assert wire_header(bytes(buf))["version"] == version
        decode_session(bytes(buf))   # optional-key compat: still decodes
    else:
        with pytest.raises(WireFormatError):
            wire_header(bytes(buf))


@settings(max_examples=30)
@given(codec=st.integers(min_value=2, max_value=255))
def test_unknown_codec_ids_rejected(codec):
    buf = bytearray(DATA)
    buf[5] = codec
    with pytest.raises(WireFormatError):
        wire_header(bytes(buf))


# -- body garbage under a valid header --------------------------------------

@settings(max_examples=30)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_random_body_with_forged_crc_rejected(seed):
    """Even an attacker who recomputes the CRC over garbage gets a
    WireFormatError from the codec/msgpack layer, not a crash."""
    import struct
    import zlib
    rng = np.random.default_rng(seed)
    body = rng.integers(0, 256, rng.integers(1, 200),
                        dtype=np.uint8).tobytes()
    hdr = struct.Struct(">4sBBI")
    magic, ver, codec, _ = hdr.unpack_from(DATA)
    forged = hdr.pack(magic, ver, codec,
                      zlib.crc32(body) & 0xFFFFFFFF) + body
    assert verify_crc(forged)        # CRC matches by construction...
    with pytest.raises(WireFormatError):
        decode_session(forged)       # ...but the body still can't decode
