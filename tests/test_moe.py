"""MoE dispatch equivalence and capacity behaviour."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as M


def _cfg(**kw):
    base = dict(name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=64, n_experts=8, top_k=2,
                d_expert=16, capacity_factor=16.0, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_dense_vs_ep_local_exact():
    cfg = _cfg()
    p, _ = M.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    a = M.moe_dense(cfg, p, x)
    b = M._moe_ep_local(cfg, p, x, n_cols=1, axis=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_ep_shardmap_matches_dense(subproc):
    subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig
    from repro.models import moe as M
    from repro.distributed.sharding import use_rules
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, n_experts=8,
                      top_k=2, d_expert=16, capacity_factor=16.0,
                      param_dtype="float32", compute_dtype="float32")
    p, _ = M.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    dense = M.moe_dense(cfg, p, x)
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
    with use_rules(mesh), mesh:
        ep = jax.jit(lambda p, x: M.moe_ep(cfg, p, x))(p, x)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ep), atol=1e-5)
    print("OK")
    """, devices=4)


def test_capacity_drops_tokens():
    """With tiny capacity, outputs differ from the no-drop case and dropped
    tokens contribute zero (residual passthrough)."""
    cfg_big = _cfg(capacity_factor=16.0)
    cfg_small = _cfg(capacity_factor=0.25)
    p, _ = M.moe_init(cfg_big, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y_big = M.moe_dense(cfg_big, p, x)
    y_small = M.moe_dense(cfg_small, p, x)
    assert not np.allclose(np.asarray(y_big), np.asarray(y_small))
    assert np.isfinite(np.asarray(y_small)).all()


def test_router_topk_renormalized():
    cfg = _cfg()
    p, _ = M.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (6, 32))
    vals, idx = M._route(cfg, p["router"], x)
    np.testing.assert_allclose(np.asarray(vals.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < cfg.n_experts


def test_sorted_positions():
    e = jnp.asarray([2, 0, 2, 1, 0, 2])
    pos = M._sorted_positions(e, 3)
    # expert 0 copies at flat idx 1,4 -> 0,1 ; expert 2 at 0,2,5 -> 0,1,2
    np.testing.assert_array_equal(np.asarray(pos), [0, 0, 1, 0, 1, 2])
