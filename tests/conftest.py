"""Shared test utilities.

NOTE: no XLA_FLAGS here by design — tests see the real 1-device CPU; tests
that need multiple host devices spawn a subprocess (see run_subprocess).
"""

import os
import subprocess
import sys
import textwrap

import pytest

# fixtures/ holds broken-on-purpose trees for the analysis suite — some
# files deliberately do not parse, and fixture test_kernels.py stubs would
# basename-collide with the real ones
collect_ignore = ["fixtures"]


def run_subprocess(code: str, devices: int = 8) -> str:
    """Run `code` in a fresh python with N fake host devices; assert rc==0."""
    prelude = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"\n'
        "import sys\n"
        f"sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})\n"
        # jax < 0.5 compat: AxisType/axis_types don't exist yet; Auto is the
        # default behaviour there, so accept-and-drop the kwarg
        "import enum, jax\n"
        "if not hasattr(jax.sharding, 'AxisType'):\n"
        "    jax.sharding.AxisType = enum.Enum('AxisType', 'Auto Explicit Manual')\n"
        "    _mm = jax.make_mesh\n"
        "    jax.make_mesh = lambda shape, names, axis_types=None, **kw: _mm(shape, names, **kw)\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"subprocess failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_subprocess
