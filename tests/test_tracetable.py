"""TraceTable API (paper §3.2/§3.3 as one store + pluggable objectives):
store semantics, cost-model behavior, and golden equivalence — the new
``TraceTable`` + ``CostModel`` searches must reproduce the legacy
``PTT.global_search``/``local_search`` and ``FleetPTT.global_search`` /
``ranked_search``/``sticky_search`` decisions on recorded traces, across
all five model families."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.places import ClusterLayout, homogeneous_layout
from repro.core.ptt import PTT, PTTConfig
from repro.core.tracetable import (Candidate, GlobalSearch, Latency,
                                   MigrationCost, Occupancy, QueueAware,
                                   RankedSearch, SearchContext, StickySearch,
                                   Sum, TraceTable)
from repro.router.fleet_ptt import FleetPTT

# the five families (dense transformer, pure SSM, hybrid, MoE, VLM): each
# contributes a differently-shaped recorded trace — latency scale from the
# config's true size, prompt mix from its modality
FAMILIES = ["smollm-135m", "mamba2-130m", "jamba-v0.1-52b",
            "granite-moe-1b-a400m", "llama-3.2-vision-90b"]


# ---------------------------------------------------------------------------
# store semantics
# ---------------------------------------------------------------------------

def test_tracetable_ema_and_bootstrap():
    t = TraceTable((2, 3), metrics=("a", "b"))
    t.update((0, 1), 10.0, "a")                  # first sample adopted
    assert t.value((0, 1), "a") == 10.0
    t.update((0, 1), 5.0, "a")                   # (4*10 + 5) / 5
    assert t.value((0, 1), "a") == pytest.approx(9.0)
    assert t.value((0, 1), "b") == 0.0           # metrics independent
    assert t.updates == 2
    assert t.trained((0, 1), "a") and not t.trained((0, 1), "b")
    mask = t.trained_mask("a")
    assert mask.shape == (2, 3) and mask[0, 1] and mask.sum() == 1


def test_tracetable_custom_window_and_merge_array():
    fast = TraceTable((3,), old_weight=1.0, den=2.0)     # 1:1 window
    fast.update((0,), 1.0)
    fast.update((0,), 3.0)
    assert fast.value((0,)) == pytest.approx(2.0)
    t = TraceTable((3,))
    t.merge_array(np.array([1.0, 2.0, 3.0]))
    np.testing.assert_allclose(t.array(), [1.0, 2.0, 3.0])
    t.merge_array(np.array([6.0, 2.0, 3.0]))             # EMA elementwise
    np.testing.assert_allclose(t.array(), [2.0, 2.0, 3.0])


def test_tracetable_snapshot_restore():
    t = TraceTable((2, 2), metrics=("m",))
    t.update((0, 0), 4.0)
    snap = t.snapshot()
    t.update((0, 0), 100.0)
    t.update((1, 1), 7.0)
    t.restore(snap)
    assert t.value((0, 0)) == 4.0
    assert not t.trained((1, 1))


# ---------------------------------------------------------------------------
# cost models
# ---------------------------------------------------------------------------

def _cand(item, width=1, tie=0.0):
    return Candidate(key=(item,), item=item, width=width, tie=tie)


def test_cost_models_basic():
    ctx = SearchContext()
    assert Latency().cost(2.0, _cand(0), ctx) == 2.0
    assert Occupancy().cost(2.0, _cand(0, width=4), ctx) == 8.0


def test_queue_aware_count_fallback_and_service_rates():
    # no service rates: classic count inflation value*tokens*(1+b)
    ctx = SearchContext(backlog=[0, 3], tokens=100)
    q = QueueAware()
    assert q.cost(0.01, _cand(0), ctx) == pytest.approx(1.0)
    assert q.cost(0.01, _cand(1), ctx) == pytest.approx(4.0)
    # with learned rates: wait = backlog x per-unit service time
    svc = {0: 0.5, 1: 0.02}.get
    ctx = SearchContext(backlog=[2, 3], tokens=100, service=svc)
    assert q.cost(0.01, _cand(0), ctx) == pytest.approx(1.0 + 2 * 0.5)
    assert q.cost(0.01, _cand(1), ctx) == pytest.approx(1.0 + 3 * 0.02)
    # a short queue on a slow replica outweighs a long one on a fast one
    assert q.cost(0.01, _cand(0), ctx) > q.cost(0.01, _cand(1), ctx)
    # absolute-value mode (TPOT rows): tokens scale composed terms like
    # MigrationCost, never the per-step value itself
    qa = QueueAware(value_per_token=False)
    assert qa.cost(0.01, _cand(1), ctx) == pytest.approx(0.01 + 3 * 0.02)
    sticky = qa + MigrationCost(per_token=1e-4)
    ctx = SearchContext(backlog=[0, 0], tokens=4096, current=1,
                        service=svc)
    # leaving home now pays the full 4096-token KV transfer, not 1 token
    assert sticky.cost(0.01, _cand(0), ctx) == pytest.approx(
        0.01 + 4096 * 1e-4)
    assert sticky.cost(0.01, _cand(1), ctx) == pytest.approx(0.01)


def test_migration_cost_and_sum_composition():
    ctx = SearchContext(tokens=1000, current=1)
    mig = MigrationCost(per_token=1e-4, fixed=0.05)
    assert mig.cost(9.9, _cand(1), ctx) == 0.0           # staying is free
    assert mig.cost(9.9, _cand(0), ctx) == pytest.approx(0.15)
    combined = Latency() + mig
    assert isinstance(combined, Sum)
    assert combined.cost(0.2, _cand(0), ctx) == pytest.approx(0.35)
    assert combined.cost(0.2, _cand(1), ctx) == pytest.approx(0.2)
    three = combined + Occupancy()
    assert three.cost(0.2, _cand(1, width=2), ctx) == pytest.approx(0.6)


def test_sticky_policy_untrained_stays_home():
    t = TraceTable((1, 3))
    t.update((0, 2), 0.001)                  # best replica trained...
    cands = [Candidate(key=(0, r), item=r) for r in range(3)]
    # ...but home (1) untrained: stay (bootstrap via routed traffic)
    ctx = SearchContext(current=1)
    assert t.search(cands, Latency(), StickySearch(2.0), ctx) == 1
    # home trained and decisively beaten (all candidates trained — an
    # untrained candidate wins the argmin and the guard stays home, same
    # as the legacy trained() check): migrate
    t.update((0, 0), 0.5)
    t.update((0, 1), 1.0)
    assert t.search(cands, Latency(), StickySearch(2.0), ctx) == 2
    # home not a candidate (unhealthy): best wins
    ctx = SearchContext(current=99)
    assert t.search(cands[2:], Latency(), StickySearch(2.0), ctx) == 2


# ---------------------------------------------------------------------------
# legacy reference implementations (the three deleted per-scale copies,
# reproduced verbatim as oracles)
# ---------------------------------------------------------------------------

def _legacy_ema(old, new):
    return new if old == 0.0 else (4.0 * old + new) / 5.0


class LegacyCorePTT:
    def __init__(self, layout, num_task_types):
        widths = layout.widths()
        self._w2i = {w: i for i, w in enumerate(widths)}
        self._tab = np.zeros((num_task_types, layout.num_cores, len(widths)))
        self._places = layout.valid_places()
        self._layout = layout

    def update(self, t, leader, width, elapsed):
        wi = self._w2i[width]
        self._tab[t, leader, wi] = _legacy_ema(self._tab[t, leader, wi],
                                               elapsed)

    def global_search(self, t, metric="occupancy"):
        best, best_cost = None, None
        for p in self._places:
            c = self._tab[t, p.leader, self._w2i[p.width]]
            c = c * p.width if metric == "occupancy" else c
            if best_cost is None or c < best_cost:
                best, best_cost = p, c
        return best

    def local_search(self, t, core):
        best, best_cost = None, None
        for w in self._layout.widths():
            try:
                p = self._layout.place_of(core, w)
            except ValueError:
                continue
            if core not in p:
                continue
            c = self._tab[t, p.leader, self._w2i[p.width]] * p.width
            if best_cost is None or c < best_cost:
                best, best_cost = p, c
        return best


class LegacyFleetPTT:
    def __init__(self, num_replicas, num_classes):
        self.n = num_replicas
        self._tab = np.zeros((num_classes, num_replicas, 2))

    def update(self, c, r, m, sample):
        self._tab[c, r, m] = _legacy_ema(self._tab[c, r, m], sample)

    def _cost(self, c, m, backlog):
        tab = self._tab[c, :, m]

        def cost(r):
            b = backlog[r] if backlog is not None else 0
            return (tab[r] * (1 + b), b)
        return cost

    def global_search(self, c, m=0, healthy=None, backlog=None):
        cand = range(self.n) if healthy is None else tuple(healthy)
        cost = self._cost(c, m, backlog)
        best, best_cost = None, None
        for r in cand:
            if best_cost is None or cost(r) < best_cost:
                best, best_cost = r, cost(r)
        return best

    def ranked_search(self, c, m=0, healthy=None, backlog=None):
        cand = range(self.n) if healthy is None else tuple(healthy)
        return sorted(cand, key=self._cost(c, m, backlog))

    def sticky_search(self, c, replica, m=1, healthy=None,
                      migrate_ratio=2.0):
        cand = range(self.n) if healthy is None else tuple(healthy)
        best = self.global_search(c, m, cand)
        if replica not in cand:
            return best
        if self._tab[c, replica, m] == 0.0 or self._tab[c, best, m] == 0.0:
            return replica
        here, there = self._tab[c, replica, m], self._tab[c, best, m]
        return best if here > migrate_ratio * there else replica

    def predict_ttft(self, c, r, backlog=0, tokens=1):
        return float(self._tab[c, r, 0] * max(tokens, 1) * (1 + backlog))


# ---------------------------------------------------------------------------
# per-family recorded traces
# ---------------------------------------------------------------------------

def _family_trace(arch, n_events=400):
    """A recorded (update, search) trace shaped by the family's config:
    latency scale follows the model's true size (layers x width), prompt
    mix follows its modality (VLM pays image tokens, SSM favors long
    prompts).  No model is built — the trace drives the *tables*."""
    cfg = get_config(arch, reduced=False)
    rng = np.random.default_rng(abs(hash(arch)) % 2 ** 32)
    scale = cfg.n_layers * cfg.d_model / 1e6
    prompts = {"vlm": (cfg.n_image_tokens + 64, 4096),
               "ssm": (2048, 32768), "hybrid": (1024, 16384)}.get(
                   cfg.family, (128, 4096))
    events = []
    for _ in range(n_events):
        kind = rng.choice(["update", "global", "local", "ranked", "sticky"])
        plen = int(rng.integers(*prompts))
        lat = float(scale * plen * rng.lognormal(0.0, 0.4) * 1e-6)
        events.append((kind, int(rng.integers(0, 3)),        # task/class
                       int(rng.integers(0, 8)),              # core/replica
                       plen, lat,
                       [int(b) for b in rng.integers(0, 6, size=8)]))
    return events


@pytest.mark.parametrize("arch", FAMILIES)
def test_golden_core_ptt_matches_legacy(arch):
    """New PTT (TraceTable + Occupancy/Latency) vs the legacy loop, step
    for step on one recorded trace per family."""
    layout = ClusterLayout(clusters=((0, 1), (2, 3, 4, 5), (6, 7)))
    new = PTT(PTTConfig(layout=layout, num_task_types=3))
    old = LegacyCorePTT(layout, num_task_types=3)
    for kind, t, core, plen, lat, _ in _family_trace(arch):
        if kind == "update":
            p = new.places[(core + plen) % len(new.places)]
            new.update(t, p.leader, p.width, lat)
            old.update(t, p.leader, p.width, lat)
        else:
            got = new.global_search(t, "occupancy" if plen % 2 else
                                    "latency")
            want = old.global_search(t, "occupancy" if plen % 2 else
                                     "latency")
            assert (got.leader, got.width) == (want.leader, want.width)
            core = core % layout.num_cores
            got, want = new.local_search(t, core), old.local_search(t, core)
            assert (got.leader, got.width) == (want.leader, want.width)
    np.testing.assert_allclose(new.trace.array(), old._tab)


@pytest.mark.parametrize("arch", FAMILIES)
def test_golden_fleet_ptt_matches_legacy(arch):
    """New FleetPTT (TraceTable + QueueAware/StickySearch) vs the legacy
    hand-rolled (latency*(1+backlog), backlog) cost, on one recorded trace
    per family — global, ranked, sticky, and predict_ttft all agree."""
    new = FleetPTT(num_replicas=8, num_classes=3)
    old = LegacyFleetPTT(num_replicas=8, num_classes=3)
    healthy_sets = [None, [0, 2, 4, 6], [1, 3, 5, 7], list(range(1, 8))]
    for i, (kind, c, r, plen, lat, backlog) in enumerate(
            _family_trace(arch)):
        healthy = healthy_sets[i % len(healthy_sets)]
        if kind == "update":
            m = i % 2
            new.update(c, r, m, lat)
            old.update(c, r, m, lat)
        elif kind == "ranked":
            assert (new.ranked_search(c, 0, healthy, backlog)
                    == old.ranked_search(c, 0, healthy, backlog))
        elif kind == "sticky":
            assert (new.sticky_search(c, r, 1, healthy)
                    == old.sticky_search(c, r, 1, healthy))
        else:
            assert (new.global_search(c, 0, healthy, backlog)
                    == old.global_search(c, 0, healthy, backlog))
            assert new.predict_ttft(c, r, backlog[r], tokens=plen) == (
                pytest.approx(old.predict_ttft(c, r, backlog[r],
                                               tokens=plen)))
    np.testing.assert_allclose(new._t.array(0), old._tab[..., 0])
    np.testing.assert_allclose(new._t.array(1), old._tab[..., 1])


def test_fleet_service_rates_change_the_decision():
    """The upgrade the legacy cost could not express: with per-replica
    service rates trained, a short queue on a slow replica loses to a
    longer queue on a fast one — count inflation alone picks the other
    way."""
    f = FleetPTT(num_replicas=2, num_classes=1)
    for r in (0, 1):
        f.update(0, r, FleetPTT.TTFT, 0.001)     # equal per-token speed
    backlog = [1, 3]
    # counts only: replica 0's shorter queue wins
    assert f.global_search(0, backlog=backlog, tokens=100) == 0
    # replica 0 is a 4x straggler per learned service rate: its 1-deep
    # queue holds more *seconds* than replica 1's 3-deep queue
    f.record_service(0, 0.8)
    f.record_service(1, 0.05)
    assert f.global_search(0, backlog=backlog, tokens=100) == 1
