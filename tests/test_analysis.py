"""Acceptance for the repro.analysis suite (lint / jaxpr audit /
contracts): each rule catches its broken fixture, annotated or guarded
sites stay clean, the CLI's JSON report is pinned to a golden file, the
donation audit fails when donation is dropped, and the real tree is
finding-free.

Regenerate the golden report after an intentional rule/format change with

    PYTHONPATH=src python tests/test_analysis.py --regen
"""

import io
import contextlib
import json
import os
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import Baseline, Finding
from repro.analysis.cli import main as analysis_main
from repro.analysis.findings import sort_findings
from repro.analysis.jaxpr_audit import (audit_decode_fused,
                                        audit_prefill_chunk,
                                        cache_leaf_names, donation_findings,
                                        jaxpr_findings)
from repro.analysis.lint import (lint_bare_retry, lint_hot_path,
                                 lint_metric_cardinality, lint_wall_clock,
                                 lint_wire_compat, run_lint)

HERE = os.path.dirname(__file__)
REPO_ROOT = os.path.abspath(os.path.join(HERE, ".."))
FIXTURE_ROOT = os.path.join(HERE, "fixtures", "analysis")
GOLDEN = os.path.join(HERE, "golden", "analysis_findings.json")


def _cli(argv) -> tuple:
    """(exit_code, stdout) of one in-process CLI invocation."""
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        rc = analysis_main(argv)
    return rc, buf.getvalue()


def _fixture_report() -> str:
    rc, out = _cli(["--only", "lint", "--root", FIXTURE_ROOT,
                    "--format", "json"])
    assert rc == 1, "broken fixture tree must gate non-zero"
    return out


# ---------------------------------------------------------------------------
# golden CLI report over the broken fixture tree
# ---------------------------------------------------------------------------

def test_fixture_report_matches_golden():
    with open(GOLDEN) as f:
        assert _fixture_report() == f.read()


def test_fixture_report_covers_every_rule():
    report = json.loads(_fixture_report())
    rules = {f["rule"] for f in report["findings"]}
    assert rules == {"hot-path-host-sync", "unguarded-span",
                     "wall-clock-latency", "wire-compat", "kernel-triad",
                     "bare-retry", "metric-cardinality", "parse-error"}
    assert report["counts"]["new"] == len(report["findings"])
    # the complete triad with a force_pallas kwarg stays finding-free
    assert not any("goodkernel" in f["path"] or "goodkernel" in f["message"]
                   for f in report["findings"])


# ---------------------------------------------------------------------------
# per-rule units: the guarded/annotated twin of each fixture stays clean
# ---------------------------------------------------------------------------

_HOT = textwrap.dedent("""\
    import numpy as np

    class ServeEngine:
        def step(self):
            toks = self._chunk()
            %s
            return toks

        def _chunk(self):
            return [1]
    """)


def test_hot_path_sync_annotation():
    bad = lint_hot_path(_HOT % "out = np.asarray(toks)", "engine.py")
    assert [f.rule for f in bad] == ["hot-path-host-sync"]
    assert bad[0].line == 6
    ok = lint_hot_path(
        _HOT % "out = np.asarray(toks)  # analysis: allow-host-sync(chunk boundary)",
        "engine.py")
    assert ok == []


def test_hot_path_only_flags_reachable_functions():
    # same sync in a method NOT reachable from the seeds: clean
    src = _HOT % "pass"
    src += "    def offline_dump(self):\n        return np.asarray([1])\n"
    assert lint_hot_path(src, "engine.py") == []


def test_unguarded_span_rule():
    guarded = _HOT % ("if self.tracer.enabled:\n"
                      "            self.tracer.instant('x', 1)")
    assert lint_hot_path(guarded, "engine.py") == []
    unguarded = _HOT % "self.tracer.instant('x', 1)"
    fs = lint_hot_path(unguarded, "engine.py")
    assert [f.rule for f in fs] == ["unguarded-span"]
    assert fs[0].severity == "warning"


def test_wall_clock_rule():
    src = "import time\nd = time.time()\n"
    fs = lint_wall_clock(src, "x.py")
    assert [f.rule for f in fs] == ["wall-clock-latency"]
    ok = "import time\nd = time.perf_counter()\nm = time.monotonic()\n"
    assert lint_wall_clock(ok, "x.py") == []


def test_bare_retry_rule():
    bad = textwrap.dedent("""\
        while True:
            try:
                ship()
            except IOError:
                continue
        """)
    fs = lint_bare_retry(bad, "x.py")
    assert [f.rule for f in fs] == ["bare-retry"]
    assert fs[0].severity == "warning"
    # geometric backoff + exhaustion raise: disciplined, clean
    ok = textwrap.dedent("""\
        delay = 0.1
        while True:
            try:
                ship()
            except IOError:
                if delay > 2.0:
                    raise
                delay *= 2
                continue
        """)
    assert lint_bare_retry(ok, "x.py") == []
    # a for-range loop is structurally capped: never flagged
    capped = textwrap.dedent("""\
        for _ in range(3):
            try:
                ship()
            except IOError:
                continue
        """)
    assert lint_bare_retry(capped, "x.py") == []
    # the annotation escape hatch
    allowed = textwrap.dedent("""\
        while True:
            try:
                ship()
            except IOError:
                # analysis: allow-bare-retry(busy-wait on local queue)
                continue
        """)
    assert lint_bare_retry(allowed, "x.py") == []


def test_metric_cardinality_rule():
    bad = textwrap.dedent("""\
        def attach(metrics, req):
            metrics.counter(f"requests_{req.rid}_total", "per request")
            metrics.gauge("tokens", "t", session_id=str(req.session_id))
            metrics.histogram("lat_seconds", "l", rid=req.rid)
            self.registry.counter("x_total", "x", key="a" + req.user)
        """)
    fs = lint_metric_cardinality(bad, "x.py")
    assert [f.rule for f in fs] == ["metric-cardinality"] * 4
    assert [f.line for f in fs] == [2, 3, 4, 5]
    assert all(f.severity == "warning" for f in fs)
    # bounded-dimension labels from plain variables are the normal idiom
    ok = textwrap.dedent("""\
        def attach(metrics, g):
            for r in range(n):
                metrics.gauge("drift_ratio", "d", fleet=g, replica=r)
            metrics.counter("served_total", "s", fleet=g, state="firing")
        """)
    assert lint_metric_cardinality(ok, "x.py") == []
    # only registry-ish receivers are in scope: a tracer instant may
    # carry ids freely (spans are bounded deques)
    tracer = 'tracer.counter = 1\nx.instant("n", rid=str(req.rid))\n'
    assert lint_metric_cardinality(tracer, "x.py") == []
    # the annotation escape hatch
    allowed = textwrap.dedent("""\
        def attach(metrics, req):
            metrics.counter(  # analysis: allow-metric-cardinality(capped)
                f"debug_{req.phase}_total", "phase is a 3-value enum")
        """)
    assert lint_metric_cardinality(allowed, "x.py") == []


def test_wire_compat_rule():
    ok = "WIRE_VERSION = 3\nWIRE_COMPAT = frozenset({1, 2, 3})\n"
    assert lint_wire_compat(ok, "wire.py") == []
    bumped = "WIRE_VERSION = 4\nWIRE_COMPAT = frozenset({1, 2, 3})\n"
    fs = lint_wire_compat(bumped, "wire.py")
    assert [f.rule for f in fs] == ["wire-compat"]
    orphan = "WIRE_VERSION = 4\n"
    assert [f.rule for f in lint_wire_compat(orphan, "wire.py")] == [
        "wire-compat"]


# ---------------------------------------------------------------------------
# baseline semantics
# ---------------------------------------------------------------------------

def _finding(msg="m"):
    return Finding("wall-clock-latency", "warning", "a.py", 7, msg)


def test_baseline_roundtrip(tmp_path):
    base = Baseline.from_findings([_finding()], reason="legacy launcher")
    p = tmp_path / "analysis_baseline.json"
    base.dump(p)
    loaded = Baseline.load(p)
    new, suppressed = loaded.apply([_finding(), _finding("other")])
    assert [f.message for f in new] == ["other"]
    assert [f.message for f in suppressed] == ["m"]
    # line moves never resurrect a suppressed finding
    moved = Finding("wall-clock-latency", "warning", "a.py", 99, "m")
    assert loaded.matches(moved)


def test_baseline_requires_reason(tmp_path):
    with pytest.raises(ValueError, match="reason"):
        Baseline([{"rule": "x", "path": "a.py"}])
    rc, _ = _cli(["--only", "lint", "--root", FIXTURE_ROOT,
                  "--write-baseline"])
    assert rc == 2                       # --write-baseline without --reason


def test_write_baseline_then_clean(tmp_path):
    bp = str(tmp_path / "analysis_baseline.json")
    rc, _ = _cli(["--only", "lint", "--root", FIXTURE_ROOT,
                  "--baseline", bp, "--write-baseline",
                  "--reason", "fixture adoption"])
    assert rc == 0
    rc, out = _cli(["--only", "lint", "--root", FIXTURE_ROOT,
                    "--baseline", bp, "--format", "json"])
    assert rc == 0                       # everything baselined -> gate green
    report = json.loads(out)
    assert report["counts"]["new"] == 0
    assert report["counts"]["baselined"] > 0


# ---------------------------------------------------------------------------
# jaxpr audit: donation, callbacks, f64
# ---------------------------------------------------------------------------

def _toy_cache():
    return {"k": jnp.zeros((2, 4, 8), jnp.float32),
            "v": jnp.zeros((2, 4, 8), jnp.float32)}


def _toy_decode(params, tok, pos, cache):
    new = {n: c + tok.astype(c.dtype).sum() for n, c in cache.items()}
    return tok + 1, new


def test_donation_audit_fails_when_donation_dropped():
    """THE regression the audit exists for: same program, donation dropped
    -> every cache leaf flagged; donated -> clean."""
    args = (jnp.zeros((2,), jnp.float32), jnp.zeros((2, 1), jnp.int32),
            jnp.zeros((2,), jnp.int32), _toy_cache())
    leaves = cache_leaf_names(args[3])
    donated = jax.jit(_toy_decode, donate_argnums=3).lower(*args).as_text()
    assert donation_findings(donated, leaves, "toy") == []
    dropped = jax.jit(_toy_decode).lower(*args).as_text()
    fs = donation_findings(dropped, leaves, "toy")
    assert [f.rule for f in fs] == ["dropped-donation", "dropped-donation"]
    assert {f.severity for f in fs} == {"error"}
    assert any("['k']" in f.message for f in fs)


def test_donation_audit_survives_pruned_args():
    """jit prunes unused arguments from the lowering, shifting argument
    numbering — the audit must match donated leaves by type, not index
    (this is exactly how the vlm family lowers: two unused param leaves)."""
    def fn(unused_a, unused_b, tok, cache):
        return tok, {n: c + 1.0 for n, c in cache.items()}
    args = (jnp.zeros((64, 64)), jnp.zeros((128,)),
            jnp.zeros((2, 1), jnp.int32), _toy_cache())
    text = jax.jit(fn, donate_argnums=3).lower(*args).as_text()
    assert donation_findings(text, cache_leaf_names(args[3]), "toy") == []


def test_jaxpr_flags_host_callback_and_f64():
    def chatty(x):
        jax.debug.print("x={x}", x=x.sum())
        return x * 2
    jaxpr = jax.make_jaxpr(chatty)(jnp.ones((2,), jnp.float32))
    rules = [f.rule for f in jaxpr_findings(jaxpr.jaxpr, "toy")]
    assert rules == ["host-callback"]

    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(lambda x: x.astype(jnp.float64) * 2)(
            jnp.ones((2,), jnp.float32))
    rules = [f.rule for f in jaxpr_findings(jaxpr.jaxpr, "toy")]
    assert rules == ["f64-promotion"]

    clean = jax.make_jaxpr(lambda x: x * 2)(jnp.ones((2,), jnp.float32))
    assert jaxpr_findings(clean.jaxpr, "toy") == []


def test_decode_fused_donation_clean_for_dense_family():
    """End-to-end: the real dense fast path keeps every KV leaf aliased
    (the other four families are covered by the CI analysis job)."""
    assert audit_decode_fused("qwen2-0.5b") == []
    assert audit_prefill_chunk("qwen2-0.5b") == []


# ---------------------------------------------------------------------------
# the merged tree is finding-free
# ---------------------------------------------------------------------------

def test_clean_tree_lint_and_contracts():
    rc, out = _cli(["--only", "lint,contracts", "--root", REPO_ROOT,
                    "--format", "json"])
    report = json.loads(out)
    assert rc == 0, report["findings"]
    assert report["counts"]["new"] == 0


def test_clean_tree_lint_findings_list_is_empty():
    # run_lint directly (no baseline): the tree itself carries zero
    # violations, the gate isn't leaning on suppressions
    assert sort_findings(run_lint(REPO_ROOT)) == []


# ---------------------------------------------------------------------------
# golden regeneration
# ---------------------------------------------------------------------------

if __name__ == "__main__" and "--regen" in sys.argv:
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    with open(GOLDEN, "w") as f:
        f.write(_fixture_report())
    print(f"regenerated {GOLDEN}")
