"""Per-architecture smoke + decode-consistency tests (reduced configs)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward(arch):
    """REDUCED config: one forward, correct shapes, no NaNs (assignment)."""
    cfg = get_config(arch, reduced=True)
    m = get_model(cfg)
    p, specs = m.init(KEY)
    # specs tree mirrors params tree
    n_p = len(jax.tree.leaves(p))
    n_s = len(jax.tree.leaves(specs,
                              is_leaf=lambda t: isinstance(t, tuple)))
    assert n_p == n_s
    logits = m.forward(p, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """REDUCED config: one train step on CPU, finite loss + grads move."""
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import make_train_step, train_state_init
    cfg = get_config(arch, reduced=True)
    m = get_model(cfg)
    state, _ = train_state_init(m, KEY, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=10))
    batch = _batch(cfg)
    batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    step = make_train_step(m, AdamWConfig(lr=1e-3, warmup_steps=1,
                                          total_steps=10))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["opt"]["step"]) == 1
    # at least one param changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert changed


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "granite-moe-1b-a400m",
                                  "jamba-v0.1-52b", "llama-3.2-vision-90b",
                                  "mamba2-130m"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode(S) logits == forward(S+1) logits at position S —
    one representative arch per family with a decode path.  MoE archs use
    no-drop capacity: token dropping legitimately depends on total token
    count (tested separately in test_moe)."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    m = get_model(cfg)
    p, _ = m.init(KEY)
    S_pre = 16
    batch = _batch(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S_pre + 1), 0,
                              cfg.vocab)
    fwd_batch = dict(batch, tokens=toks)
    fwd_batch.pop("frames", None)
    lg_full = m.forward(p, fwd_batch)
    pre_batch = dict(fwd_batch, tokens=toks[:, :S_pre])
    lg_pre, cache = m.prefill(p, pre_batch)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]),
                               np.asarray(lg_full[:, S_pre - 1]),
                               rtol=1e-4, atol=1e-4)

    # pad cache to the decode-time spec shapes (seq dims grow to Smax)
    Smax = S_pre + 8
    spec = m.cache_spec(B, Smax)

    def pad(v, s):
        pads = [(0, sd - vd) for vd, sd in zip(v.shape, s.shape)]
        return jnp.pad(v, pads)

    cache = jax.tree.map(pad, cache, spec)
    lg_dec, _ = m.decode(p, toks[:, S_pre:S_pre + 1], jnp.asarray(S_pre),
                         cache)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(lg_full[:, S_pre]),
                               rtol=1e-4, atol=1e-4)


def test_cache_spec_matches_prefill():
    """cache_spec structure must match what prefill returns (decode relies
    on it for the dry-run)."""
    for arch in ("qwen2-0.5b", "jamba-v0.1-52b", "llama-3.2-vision-90b",
                 "mamba2-130m", "granite-moe-1b-a400m"):
        cfg = get_config(arch, reduced=True)
        m = get_model(cfg)
        p, _ = m.init(KEY)
        batch = _batch(cfg)
        if "frames" in batch:
            continue
        _, cache = m.prefill(p, batch)
        spec = m.cache_spec(B, S)
        assert set(jax.tree_util.tree_structure(cache).node_data()[1]) == set(
            jax.tree_util.tree_structure(spec).node_data()[1])
