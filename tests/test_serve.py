"""Serving engine: generated tokens must match a direct greedy decode."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Request, ServeEngine


def _greedy_reference(m, p, prompt, n_new, vocab):
    toks = list(prompt)
    for _ in range(n_new):
        logits = m.forward(p, {"tokens": jnp.asarray(toks)[None, :]})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_greedy_decode():
    cfg = get_config("qwen2-0.5b", reduced=True)
    m = get_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(3)]
    engine = ServeEngine(m, p, max_batch=4, max_seq=32)
    reqs = [Request(rid=i, prompt=pr, max_new=6)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained(max_steps=100)
    for r, pr in zip(reqs, prompts):
        assert r.done
        ref = _greedy_reference(m, p, pr, 6, cfg.vocab)
        assert r.out_tokens[:6] == ref, (r.out_tokens, ref)


def test_engine_waves_and_queueing():
    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(1))
    engine = ServeEngine(m, p, max_batch=2, max_seq=24)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6), max_new=4)
            for i in range(5)]           # more requests than batch slots
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) >= 4 for r in reqs)
    # the PTT saw both prefill (critical) and decode (non-critical) updates
    assert engine.scheduler.ptt.updates > len(reqs)
