"""Serving engine: generated tokens must match a direct greedy decode."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Request, ServeEngine


def _greedy_reference(m, p, prompt, n_new, vocab):
    toks = list(prompt)
    for _ in range(n_new):
        logits = m.forward(p, {"tokens": jnp.asarray(toks)[None, :]})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_greedy_decode():
    cfg = get_config("qwen2-0.5b", reduced=True)
    m = get_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(3)]
    engine = ServeEngine(m, p, max_batch=4, max_seq=32)
    reqs = [Request(rid=i, prompt=pr, max_new=6)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained(max_steps=100)
    for r, pr in zip(reqs, prompts):
        assert r.done
        ref = _greedy_reference(m, p, pr, 6, cfg.vocab)
        assert r.out_tokens[:6] == ref, (r.out_tokens, ref)


def test_ragged_admission_mixed_prompt_lengths():
    """Continuous batching: one admission round takes prompts of different
    lengths into one batch (the wave engine admitted only equal-length
    prompts into an empty batch) and still matches greedy decode."""
    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n) for n in (5, 9, 7)]
    engine = ServeEngine(m, p, max_batch=4, max_seq=32)
    reqs = [Request(rid=i, prompt=pr, max_new=5)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.step()
    assert engine.active_count() == 3        # all admitted despite raggedness
    engine.run_until_drained(max_steps=100)
    for r, pr in zip(reqs, prompts):
        assert r.done
        ref = _greedy_reference(m, p, pr, 5, cfg.vocab)
        assert r.out_tokens[:5] == ref, (r.rid, r.out_tokens, ref)


def test_admission_into_occupied_batch():
    """A free slot admits a new prompt while other slots are mid-decode —
    no waiting for the batch to drain."""
    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    engine = ServeEngine(m, p, max_batch=2, max_seq=32)
    first = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6), max_new=8)
    engine.submit(first)
    engine.step()
    engine.step()
    assert not first.done and engine.active_count() == 1
    late = Request(rid=1, prompt=rng.integers(0, cfg.vocab, 4), max_new=4)
    engine.submit(late)
    engine.step()                            # admits next to the live slot
    assert engine.active_count() == 2
    engine.run_until_drained(max_steps=100)
    for r, n in ((first, 8), (late, 4)):
        assert r.done
        ref = _greedy_reference(m, p, r.prompt, n, cfg.vocab)
        assert r.out_tokens[:n] == ref, (r.rid, r.out_tokens, ref)


def test_step_latency_hook_only_fires_on_decode():
    """A step that only admits (every admission finished at prefill) must
    not feed a zero/stale latency into on_step_latency — the interference
    detector needs a homogeneous decode-only signal."""
    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    engine = ServeEngine(m, p, max_batch=2, max_seq=24)
    seen = []
    engine.on_step_latency = seen.append
    engine.step()                            # idle step: no signal
    assert seen == [] and engine.last_step_latency == 0.0
    one = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6), max_new=1)
    engine.submit(one)
    assert engine.step() == 0                # admit-only: done at prefill
    assert one.done and seen == []
    assert engine.last_step_latency == 0.0
    two = Request(rid=1, prompt=rng.integers(0, cfg.vocab, 6), max_new=3)
    engine.submit(two)
    engine.step()                            # real decode: signal fires
    assert len(seen) == 1 and seen[0] > 0.0
    assert engine.last_step_latency == seen[0]


def test_engine_queueing_more_requests_than_slots():
    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(1))
    engine = ServeEngine(m, p, max_batch=2, max_seq=24)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6), max_new=4)
            for i in range(5)]           # more requests than batch slots
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) >= 4 for r in reqs)
    # the PTT saw both prefill (critical) and decode (non-critical) updates
    assert engine.scheduler.ptt.updates > len(reqs)
