"""Region tier: session wire format, WAN-aware routing, and cross-region
failover.

The acceptance bar for the fourth PTT scale: a browned-out fleet's live
sessions drain to the WAN-cost-best healthy fleet *through the versioned
byte wire format* (never an in-process object handoff) with greedy token
streams identical to uninterrupted decode — and a session whose WAN move
doesn't pay (MigrationCost + WanCost ranked search puts the source first)
is never even exported."""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.tracetable import (Candidate, MigrationCost, QueueAware,
                                   SearchContext, TraceTable, WanCost)
from repro.models import get_model
from repro.region import (LoopbackTransport, RegionGateway, RegionRouter,
                          WIRE_COMPAT, WIRE_VERSION, WireFormatError,
                          decode_session, encode_session, wire_header)
from repro.router import FleetGateway
from repro.serve import Request, ServeEngine, Session


def _synthetic_session() -> Session:
    rng = np.random.default_rng(0)
    req = Request(rid=7, prompt=np.arange(5, dtype=np.int64), max_new=9,
                  tenant="acme",
                  extras={"image_embeds": rng.normal(
                      size=(2, 3)).astype(np.float32)},
                  out_tokens=[1, 2, 3], t_first=1.5, t_admit=1.25)
    return Session(req=req, pos=8, cur_token=3,
                   cache={"k": rng.normal(size=(1, 2, 8, 4)).astype(
                       np.float32),
                          "state": rng.normal(size=(1, 4)).astype(
                       np.float64)})


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

def test_wire_round_trip_preserves_session():
    sess = _synthetic_session()
    out = decode_session(encode_session(sess))
    assert out.req is not sess.req           # a NEW object crossed: bytes,
    assert out.pos == sess.pos               # not an in-process handoff
    assert out.cur_token == sess.cur_token
    assert out.req.rid == sess.req.rid
    assert out.req.max_new == sess.req.max_new
    assert out.req.tenant == sess.req.tenant
    assert out.req.out_tokens == sess.req.out_tokens
    assert out.req.t_first == sess.req.t_first
    assert np.array_equal(out.req.prompt, sess.req.prompt)
    for k in sess.cache:
        assert np.array_equal(out.cache[k], sess.cache[k])
        assert out.cache[k].dtype == sess.cache[k].dtype
    for k in sess.req.extras:
        assert np.array_equal(out.req.extras[k], sess.req.extras[k])


def test_wire_header_records_codec_and_version():
    from repro.checkpoint import default_codec
    data = encode_session(_synthetic_session())
    h = wire_header(data)
    assert h["version"] == WIRE_VERSION
    # the checkpoint codec path is reused: zstd when importable, zlib
    # fallback otherwise — whichever this build wrote is in the header
    assert h["codec"] == default_codec()
    assert h["nbytes"] == len(data)
    # explicit zlib always encodes and round-trips on any build
    z = encode_session(_synthetic_session(), codec="zlib")
    assert wire_header(z)["codec"] == "zlib"
    assert decode_session(z).pos == 8


def test_wire_rejects_corrupt_and_foreign_payloads():
    data = encode_session(_synthetic_session())
    # flipped payload byte: checksum catches it before any deserialization
    bad = bytearray(data)
    bad[-1] ^= 0xFF
    with pytest.raises(WireFormatError, match="checksum"):
        decode_session(bytes(bad))
    # truncation
    with pytest.raises(WireFormatError, match="checksum"):
        decode_session(data[:-3])
    with pytest.raises(WireFormatError, match="too short"):
        decode_session(data[:4])
    # foreign bytes
    with pytest.raises(WireFormatError, match="magic"):
        decode_session(b"XXXX" + data[4:])
    # any version outside the compat set must refuse, not misparse — the
    # CRC covers only the body, so both a future version and a corrupted
    # version byte (2 -> 0) land here
    for v in (WIRE_VERSION + 1, 0):
        assert v not in WIRE_COMPAT
        fut = bytearray(data)
        fut[4] = v
        with pytest.raises(WireFormatError, match="version"):
            decode_session(bytes(fut))
    # unknown codec id
    unk = bytearray(data)
    unk[5] = 99
    with pytest.raises(WireFormatError, match="codec"):
        decode_session(bytes(unk))
    with pytest.raises(WireFormatError):
        encode_session(_synthetic_session(), codec="lz4")


def test_wire_v1_payload_still_decodes():
    """Backward compat: v2/v3/v4 each only added an optional payload key,
    so a v1 payload — same layout, version byte 1, no "trace"/"prefilled"/
    "delivery" keys — must decode unchanged (trace=None, prefilled=None,
    delivery=None), while versions outside WIRE_COMPAT raise."""
    assert WIRE_VERSION == 4 and WIRE_COMPAT == frozenset({1, 2, 3, 4})
    sess = _synthetic_session()
    assert sess.trace is None
    data = bytearray(encode_session(sess))      # v4 writer, no optional
    data[4] = 1                                 # keys: byte-identical to a
    out = decode_session(bytes(data))           # v1 writer's output
    assert wire_header(bytes(data))["version"] == 1
    assert out.pos == sess.pos and out.trace is None
    assert out.prefilled is None
    assert out.delivery is None
    assert out.req.out_tokens == sess.req.out_tokens
    for k in sess.cache:
        assert np.array_equal(out.cache[k], sess.cache[k])


def test_wire_carries_trace_context():
    """v2's optional trace field: present -> round-trips verbatim; the
    migrated request's causal identity survives the byte boundary."""
    sess = _synthetic_session()
    sess.trace = {"trace_id": "fleetA/r7"}
    out = decode_session(encode_session(sess))
    assert out.trace == {"trace_id": "fleetA/r7"}
    assert wire_header(encode_session(sess))["version"] == WIRE_VERSION


def test_engine_wire_round_trip_token_identity():
    """export_session_wire -> bytes -> import_session_wire resumes the
    exact greedy stream (the serve-engine surface of the wire format)."""
    cfg = get_config("smollm-135m", reduced=True)
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 6)

    ref = Request(rid=0, prompt=prompt.copy(), max_new=10)
    e = ServeEngine(m, params, max_batch=2, max_seq=48)
    e.submit(ref)
    e.run_until_drained(200)

    mig = Request(rid=1, prompt=prompt.copy(), max_new=10)
    a = ServeEngine(m, params, max_batch=2, max_seq=48)
    b = ServeEngine(m, params, max_batch=2, max_seq=48)
    a.submit(mig)
    for _ in range(3):
        a.step()
    data = a.export_session_wire(mig.rid)
    assert wire_header(data)["nbytes"] == len(data)
    b.import_session_wire(data)
    handle = b.sessions_in[0].req            # the decoded copy that will
    assert handle is not mig                 # finish the generation
    assert handle.rid == mig.rid
    b.run_until_drained(200)
    assert handle.done
    assert not mig.done                      # original froze at export
    assert handle.out_tokens[:10] == ref.out_tokens[:10], (
        handle.out_tokens, ref.out_tokens)


# ---------------------------------------------------------------------------
# WanCost
# ---------------------------------------------------------------------------

def test_wan_cost_charges_hops_and_learns_links():
    links = TraceTable((3, 3), metrics=("rtt",))
    wan = WanCost(links, egress_per_byte=1e-9, bytes_per_token=1000.0)
    cand = lambda f: Candidate(key=(0, f), item=f)
    ctx = SearchContext(tokens=2048, origin=0)
    # staying home is free; untrained link charges egress only
    assert wan.cost(0.0, cand(0), ctx) == 0.0
    assert wan.cost(0.0, cand(1), ctx) == pytest.approx(
        1e-9 * 1000.0 * 2048)
    # the link row is the paper's EMA: first sample adopted, then 4:1
    links.update((0, 1), 0.1)
    assert wan.rtt(0, 1) == pytest.approx(0.1)
    links.update((0, 1), 0.2)
    assert wan.rtt(0, 1) == pytest.approx((4 * 0.1 + 0.2) / 5)
    assert wan.cost(0.0, cand(1), ctx) == pytest.approx(
        wan.rtt(0, 1) + 1e-9 * 1000.0 * 2048)
    # origin falls back to ctx.current (sticky composition) and the model
    # composes additively with QueueAware + MigrationCost
    ctx2 = SearchContext(tokens=100, current=0)
    composed = QueueAware(value_per_token=False) + wan + MigrationCost(
        fixed=0.5)
    assert composed.cost(0.0, cand(1), ctx2) == pytest.approx(
        wan.rtt(0, 1) + 1e-9 * 1000.0 * 100 + 0.5)
    assert composed.cost(0.0, cand(0), ctx2) == 0.0


def test_region_sticky_affinity_weighs_wan_cost():
    """A chatty decode stays on its home fleet when the WAN hop outweighs
    the TPOT win, and leaves when the link is cheap and the win decisive."""
    expensive = RegionRouter(2)
    cheap = RegionRouter(2)
    for rr, rtt in ((expensive, 1.0), (cheap, 0.001)):
        for _ in range(6):
            rr.record_tpot(0, 0.1)      # home: slow decode
            rr.record_tpot(1, 0.01)     # away: 10x faster
            rr.record_rtt(0, 1, rtt)
    d = expensive.route(16, 256, origin=0, affinity=0)
    assert d.fleet == 0 and not d.wan_hop
    d = cheap.route(16, 256, origin=0, affinity=0)
    assert d.fleet == 1 and d.wan_hop


def test_region_route_reports_hop_from_the_charged_home():
    """When the affinity fleet is browned out the search runs globally
    from the ingress region — and the decision reports hops against that
    same home, not the dead affinity (no phantom wan_hop/predicted RTT)."""
    rr = RegionRouter(2)
    rr.record_rtt(1, 0, 0.2)
    rr.brownout(0)
    d = rr.route(16, 256, origin=1, affinity=0)
    assert d.fleet == 1
    assert not d.wan_hop                     # served at the ingress region
    assert d.predicted == pytest.approx(0.0)  # untrained rows, no RTT added


# ---------------------------------------------------------------------------
# region failover (real engines, wire transport)
# ---------------------------------------------------------------------------

def _build_region(arch: str, n_fleets: int = 2, engines_per_fleet: int = 1,
                  router: RegionRouter | None = None,
                  link_rtt=None):
    cfg = get_config(arch, reduced=True)
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    fleets = [FleetGateway([ServeEngine(m, params, max_batch=2, max_seq=48)
                            for _ in range(engines_per_fleet)])
              for _ in range(n_fleets)]
    tr = LoopbackTransport(link_rtt=link_rtt)
    return cfg, m, params, RegionGateway(
        fleets, router=router or RegionRouter(n_fleets), transport=tr)


@pytest.mark.parametrize("arch", ("smollm-135m", "granite-moe-1b-a400m"))
def test_region_failover_token_identity(arch):
    """Region-wide brownout drains every live session cross-region through
    the wire format with byte-identical greedy continuation — across
    attention-cache and MoE families."""
    cfg, m, params, rg = _build_region(arch, link_rtt=lambda s, d: 0.08)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 6) for _ in range(3)]
    max_new = 10

    refs = []
    for i, p in enumerate(prompts):
        e = ServeEngine(m, params, max_batch=2, max_seq=48)
        r = Request(rid=100 + i, prompt=p.copy(), max_new=max_new)
        e.submit(r)
        e.run_until_drained(200)
        refs.append(list(r.out_tokens))

    reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        d = rg.submit(r, origin=0, affinity=0)
        assert d.fleet == 0                  # sticky: everything starts home
    for _ in range(3):
        rg.pump()
    rg.brownout(0)
    rg.pump()
    # the browned-out fleet is EMPTY after one pump: all live sessions left
    assert sum(e.active_count() + e.pending()
               for e in rg.fleets[0].engines) == 0
    st = rg.stats()
    assert st["wan_ships"] >= 1 and st["wan_bytes"] > 0
    # learned link row trained from the drain's observed delivery time
    assert st["rtt_rows"][0][1] == pytest.approx(0.08)

    rg.run_until_drained(500)
    for i, ref in enumerate(refs):
        h = rg.request(i)
        assert h.done
        assert h.out_tokens[:max_new] == ref[:max_new], (
            arch, i, h.out_tokens, ref)
    # at least one live handle is a decoded copy — proof the drain went
    # through bytes, not an in-process object handoff
    assert any(rg.request(i) is not reqs[i] for i in range(len(reqs)))


@pytest.mark.parametrize("kind", ("wan", "migration"))
def test_region_stay_home_skips_export(kind):
    """When the ranked MigrationCost + WanCost search puts the browned-out
    source first, the session is never exported: no wire bytes move and
    the request finishes (slowly) where its cache already is."""
    if kind == "wan":
        router = RegionRouter(2, egress_per_byte=1.0, bytes_per_token=1e6)
    else:
        router = RegionRouter(2, migration=MigrationCost(fixed=1e9))
    cfg, m, params, rg = _build_region("smollm-135m", router=router)
    # train TPOT rows so the ranked search runs on evidence, not bootstrap
    for _ in range(4):
        rg.router.record_tpot(0, 0.01)
        rg.router.record_tpot(1, 0.01)
    rng = np.random.default_rng(0)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6), max_new=10)
    rg.submit(req, origin=0, affinity=0)
    for _ in range(3):
        rg.pump()
    assert not req.done
    rg.brownout(0)
    rg.pump()
    st = rg.stats()
    assert st["stay_home_skips"] >= 1
    assert st["wan_ships"] == 0 and st["wan_bytes"] == 0
    rg.run_until_drained(500)
    assert req.done                          # finished on the browned-out
    assert rg.request(0) is req              # fleet: the original handle


def test_region_drain_reroutes_unstarted_requests():
    """Queued-but-unstarted requests on a browned-out fleet re-route to a
    healthy fleet as plain requests (no cache state -> no wire cost)."""
    cfg, m, params, rg = _build_region("smollm-135m")
    rng = np.random.default_rng(0)
    # more requests than fleet 0's slots so some stay queued
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6), max_new=8)
            for i in range(5)]
    for r in reqs:
        rg.submit(r, origin=0, affinity=0)
    rg.pump()
    rg.brownout(0)
    rg.run_until_drained(500)
    assert all(rg.request(r.rid).done for r in reqs)
    assert rg.fleets[1].stats()["served"] >= 1
