"""Fused decode fast path: donated-cache k-token scan decode (greedy argmax
on device) and the ragged Pallas decode-attention kernel must produce
byte-identical greedy token streams vs the legacy per-step path, on every
model family — including a mid-chunk finish (max_new not divisible by the
chunk) and a session export/import after the cache has been donated."""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.kernels.ragged_decode import force_pallas, ragged_decode_attention
from repro.kernels.ragged_decode.ref import ragged_decode_ref
from repro.models import get_model
from repro.serve import Request, ServeEngine

FAMILY_ARCHS = ("qwen2-0.5b", "granite-moe-1b-a400m", "mamba2-130m",
                "jamba-v0.1-52b", "llama-3.2-vision-90b")

MAX_SEQ = 32


def _setup(arch, seed=0):
    cfg = get_config(arch, reduced=True)
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(seed))
    return cfg, m, params


def _requests(cfg, rng, n, max_new):
    reqs = []
    for i in range(n):
        extras = {}
        if cfg.family == "vlm":
            extras["image_embeds"] = np.asarray(
                jax.random.normal(jax.random.PRNGKey(7),
                                  (cfg.n_image_tokens, cfg.d_model)))
        reqs.append(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6),
                            max_new=max_new, extras=extras))
    return reqs


def _decode_all(m, params, reqs, *, fused, chunk=1, max_batch=2):
    engine = ServeEngine(m, params, max_batch=max_batch, max_seq=MAX_SEQ,
                         decode_chunk=chunk, fused=fused)
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained(max_steps=200)
    assert all(r.done for r in reqs)
    return [list(r.out_tokens) for r in reqs]


def _clone(reqs):
    return [Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                    extras=dict(r.extras)) for r in reqs]


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
@pytest.mark.parametrize("chunk", (1, 4))
def test_fused_scan_decode_token_identity(arch, chunk):
    """Fused k-token decode (donated cache, device argmax) vs the legacy
    per-step path.  max_new=6 is not divisible by 4, so chunk=4 exercises
    the mid-chunk finish: the engine must truncate the surplus tokens the
    chunk decoded past max_new."""
    cfg, m, params = _setup(arch)
    rng = np.random.default_rng(0)
    ref_reqs = _requests(cfg, rng, 2, max_new=6)
    ref = _decode_all(m, params, ref_reqs, fused=False)
    got = _decode_all(m, params, _clone(ref_reqs), fused=True, chunk=chunk)
    assert got == ref, (arch, chunk, got, ref)
    assert all(len(t) == 6 for t in got)         # surplus truncated exactly


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_export_import_after_donation_token_identity(arch):
    """A session exported AFTER the donated fast path has been running (the
    original cache buffers are long dead) must carry valid host-side state:
    resuming it on another fused engine reproduces the unmigrated greedy
    stream."""
    cfg, m, params = _setup(arch, seed=1)
    rng = np.random.default_rng(1)
    ref_reqs = _requests(cfg, rng, 1, max_new=8)
    ref = _decode_all(m, params, ref_reqs, fused=False)

    mig = _clone(ref_reqs)[0]
    a = ServeEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                    decode_chunk=2, fused=True)
    b = ServeEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                    decode_chunk=2, fused=True)
    a.submit(mig)
    for _ in range(2):                 # 1 prefill token + 2 fused chunks
        a.step()
    assert not mig.done
    sess = a.export_session(mig.rid)
    assert all(isinstance(v, np.ndarray) for v in sess.cache.values())
    b.import_session(sess)
    b.run_until_drained(max_steps=200)
    assert mig.done
    assert list(mig.out_tokens) == ref[0], (arch, mig.out_tokens, ref[0])


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_ragged_pallas_kernel_token_identity(arch):
    """The Pallas ragged decode-attention kernel (interpret mode on CPU),
    driven through the full fused decode, matches the per-step reference
    path token for token.  The kernel choice is baked in at trace time, so
    a fresh Model (fresh jit cache) is built inside the force context."""
    cfg, m, params = _setup(arch, seed=2)
    rng = np.random.default_rng(2)
    ref_reqs = _requests(cfg, rng, 2, max_new=4)
    ref = _decode_all(m, params, ref_reqs, fused=False)
    with force_pallas():
        m2 = get_model(cfg)            # fresh traces pick up the kernel
        got = _decode_all(m2, params, _clone(ref_reqs), fused=True, chunk=2)
    assert got == ref, (arch, got, ref)


def test_ragged_kernel_matches_reference_numerically():
    """Direct op-level check: GQA, ragged per-slot positions, and a cache
    length that does not divide the k-block."""
    rng = np.random.default_rng(3)
    for (B, Smax, Hq, Hkv, hd, bk) in ((4, 32, 8, 2, 16, 8),
                                       (3, 19, 6, 6, 8, 8)):
        import jax.numpy as jnp
        q = jnp.asarray(rng.normal(size=(B, Hq, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Smax, Hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Smax, Hkv, hd)), jnp.float32)
        pos = jnp.asarray(rng.integers(0, Smax, B), jnp.int32)
        ref = ragged_decode_ref(q, k, v, pos)
        with force_pallas():
            out = ragged_decode_attention(q, k, v, pos, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # and the default (CPU) route IS the reference
    got = ragged_decode_attention(q, k, v, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_donated_cache_is_consumed():
    """Contract check: after a fused decode dispatch the old cache buffers
    are dead (donated) — holding on to them is a bug the engine must never
    have.  Guards against silently losing `donate_argnums` in a refactor
    (the copy-per-token would come back with no functional symptom)."""
    import jax.numpy as jnp
    cfg, m, params = _setup("smollm-135m")
    spec = m.cache_spec(2, MAX_SEQ)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    _, _, _, cache2 = m.decode_fused(params, tok, pos, cache, 2)
    jax.tree.leaves(cache2)[0].block_until_ready()
    leaf = jax.tree.leaves(cache)[0]
    with pytest.raises(RuntimeError):
        np.asarray(leaf)               # donated: buffer deleted
